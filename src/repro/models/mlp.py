"""Dense MLP variants: SwiGLU, GeGLU, GELU, squared-ReLU (Nemotron)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(kind: str, x):
    if kind in ("swiglu",):
        return jax.nn.silu(x)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind}")


def is_gated(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


def mlp_forward(params, x, kind: str):
    """x: [..., d]. Gated kinds use fused wi: [d, 2, ff]."""
    if is_gated(kind):
        h = jnp.einsum("...d,dgf->...gf", x, params["wi"])
        h = _act(kind, h[..., 0, :]) * h[..., 1, :]
    else:
        h = _act(kind, jnp.einsum("...d,df->...f", x, params["wi"]))
    return jnp.einsum("...f,fd->...d", h, params["wo"])
