"""§Perf hillclimb driver: run a (arch, shape, mesh) pair under a named set
of optimization variants, re-lower + re-analyze, and log
hypothesis -> change -> before -> after into experiments/perf/.

Must be launched as its own process (needs 512 host devices):
  PYTHONPATH=src python -m benchmarks.perf_iterations --pair deepseek_train
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh, sharding_rules
from repro.launch.roofline import analyze, memory_summary
from repro.launch.steps import lower_step
from repro.profiling.cost_model import model_flops_6nd


def _rules_no_moe_fsdp(multi_pod):
    r = sharding_rules(multi_pod)
    r["moe_embed"] = None  # experts sharded over model only: no per-layer gather
    return r


PAIRS = {
    # (arch, shape, list of (variant_name, hypothesis, kwargs for lower_step))
    "deepseek_train": ("deepseek-v3-671b", "train_4k", [
        ("baseline", "paper-faithful FSDP-everything baseline", {}),
        ("no_moe_fsdp",
         "expert weights are re-gathered over the data axis every layer "
         "(58 x ~1.4 GB all-gather); storing them model-sharded only should "
         "cut the collective term by the expert-gather share at +1.3 GB/dev "
         "memory", {"rules": "no_moe_fsdp"}),
        ("no_moe_fsdp_cap1",
         "capacity factor 1.25 pads every a2a bucket by 25%; dropping to "
         "1.0 shrinks a2a traffic ~20% at slightly higher drop rate",
         {"rules": "no_moe_fsdp", "capacity": 1.0}),
        ("no_moe_fsdp_mb4",
         "temp memory is activation-dominated; 4 microbatches should cut "
         "activation temp ~4x at unchanged FLOPs (collective per-step "
         "unchanged, repeated 4x smaller)",
         {"rules": "no_moe_fsdp", "microbatches": 4}),
    ]),
    "nemotron_train": ("nemotron-4-340b", "train_4k", [
        ("baseline", "paper-faithful baseline", {}),
        ("mb4",
         "340B dense: weights+opt args ~13 GB/dev leave no activation room; "
         "4 microbatches cut activation temp ~4x, FLOPs unchanged",
         {"microbatches": 4}),
        ("mb8", "8 microbatches: further temp cut, diminishing returns "
         "once weight gathers dominate", {"microbatches": 8}),
    ]),
    "gemma3_long": ("gemma3-27b", "long_500k", [
        ("baseline",
         "default long-context variant: ALL layers windowed (W=1024); "
         "memory term should be tiny but quality-lossy for globals", {}),
        ("global_full_cache",
         "keep the 10-11 global layers' caches FULL (524k, seq-sharded over "
         "data): memory term rises by ~2.7 GB/dev of cache reads per step "
         "but restores exact global attention",
         {"rt": {"long_context": False}}),
    ]),
    "gemma2_train": ("gemma2-2b", "train_4k", [
        ("baseline", "paper-faithful baseline", {}),
        ("mb4", "activation temp (44 GB) is ~6x the 7.9 GB f32 carry stack; "
         "4 microbatches cut it ~4x", {"microbatches": 4}),
        ("no_remat", "remat trades 1.33x flops for memory; without it the "
         "compute term drops but temp explodes (refutation check)",
         {"rt": {"remat": False}}),
        ("seqpar",
         "gemma2's 8 q-heads cannot shard over model=16, so attention "
         "compute is REPLICATED per device (~16x waste on the score/AV "
         "matmuls); sequence-parallel attention (queries sharded along seq "
         "over the model axis, K/V gathered) should cut the compute term "
         "several-fold for +0.5 GB/layer of K/V all-gather traffic",
         {"rt": {"seq_parallel_attn": True}}),
        ("seqpar_mb4", "combine both confirmed wins",
         {"rt": {"seq_parallel_attn": True}, "microbatches": 4}),
    ]),
}


def run_pair(name: str, multi_pod: bool = False):
    arch, shape_name, variants = PAIRS[name]
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    results = []
    for vname, hypothesis, kw in variants:
        kwargs = {}
        if kw.get("rules") == "no_moe_fsdp":
            kwargs["rules"] = _rules_no_moe_fsdp(multi_pod)
        if "microbatches" in kw:
            kwargs["microbatches"] = kw["microbatches"]
        if "rt" in kw:
            kwargs["rt_overrides"] = kw["rt"]
        cfg_v = cfg
        if "capacity" in kw:
            import dataclasses
            cfg_v = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe,
                                             capacity_factor=kw["capacity"]))
        lowered, meta = lower_step(cfg_v, mesh, shape, **kwargs)
        compiled = lowered.compile()
        if shape.kind == "train":
            mf = model_flops_6nd(cfg, shape.global_batch, shape.seq_len) / mesh.size
        else:
            mf = 2.0 * cfg.active_param_count() * shape.global_batch / mesh.size
        roof = analyze(compiled, model_flops_per_device=mf)
        mem = memory_summary(compiled)
        row = {
            "variant": vname, "hypothesis": hypothesis,
            "compute_s": roof.compute_s, "memory_s": roof.memory_s,
            "collective_s": roof.collective_s, "dominant": roof.dominant,
            "flops": roof.flops, "bytes": roof.bytes_accessed,
            "coll_bytes": roof.coll_bytes,
            "args_gb": mem.get("argument_size_in_bytes", 0) / 1e9,
            "temp_gb": mem.get("temp_size_in_bytes", 0) / 1e9,
            "useful_ratio": roof.useful_ratio,
        }
        results.append(row)
        print(f"[perf:{name}] {vname}: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"temp={row['temp_gb']:.1f}GB dominant={roof.dominant}")
    os.makedirs("experiments/perf", exist_ok=True)
    with open(f"experiments/perf/{name}.json", "w") as f:
        json.dump(results, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=sorted(PAIRS), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    run_pair(args.pair, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
