import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and dump roofline terms.

This file MUST set XLA_FLAGS before any other import (jax locks the device
count on first init) — hence the two lines above.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, INPUT_SHAPES, get_config, shape_supported
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, memory_summary
from repro.launch.steps import lower_step
from repro.profiling.cost_model import model_flops_6nd


def run_one(arch: str, shape_name: str, multi_pod: bool,
            *, rt_overrides=None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.perf_counter()
    lowered, meta = lower_step(cfg, mesh, shape, rt_overrides=rt_overrides)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    if shape.kind == "train":
        mf = model_flops_6nd(cfg, shape.global_batch, shape.seq_len) / n_chips
    else:
        # fwd-only: 2 N D (decode: D = batch tokens)
        toks = (shape.global_batch * shape.seq_len
                if shape.kind == "prefill" else shape.global_batch)
        mf = 2.0 * cfg.active_param_count() * toks / n_chips

    hlo = compiled.as_text()
    roof = analyze(compiled, model_flops_per_device=mf, hlo_text=hlo)
    mem = memory_summary(compiled)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips, "kind": meta["kind"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "roofline": roof.to_dict(),
        "hlo_bytes": len(hlo),
    }
    if verbose:
        ma = mem
        per_dev_gb = (ma.get("argument_size_in_bytes", 0)
                      + ma.get("temp_size_in_bytes", 0)) / 1e9
        print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
              f"compile={t_compile:.1f}s args+temp={per_dev_gb:.2f}GB/dev "
              f"flops/dev={roof.flops:.3e} dominant={roof.dominant}")
        print(f"         memory_analysis: {ma}")
        print(f"         cost_analysis: flops={roof.flops:.4e} "
              f"bytes={roof.bytes_accessed:.4e} coll={roof.coll_bytes}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON results")
    args = ap.parse_args()

    combos = []
    archs = sorted(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = (sorted(INPUT_SHAPES) if (args.all or args.shape is None)
              else [args.shape])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            if shape_supported(get_config(a), s):
                for mp in meshes:
                    combos.append((a, s, mp))

    results, failures = [], []
    for a, s, mp in combos:
        tag = f"{a}__{s}__{'multi' if mp else 'single'}"
        if args.out and os.path.exists(os.path.join(args.out, tag + ".json")):
            print(f"[dryrun] skip {tag} (done)")
            continue
        try:
            res = run_one(a, s, mp)
            results.append(res)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            failures.append((tag, str(e)[:500]))
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                with open(os.path.join(args.out, tag + ".FAILED.json"), "w") as f:
                    json.dump({"tag": tag, "error": str(e)[:2000]}, f)
    print(f"\n[dryrun] {len(results)} ok, {len(failures)} failed "
          f"out of {len(combos)}")
    for tag, err in failures:
        print(f"  FAIL {tag}: {err[:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
