"""Roofline-term extraction from compiled dry-run artifacts.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (we budget 45 GB/s effective per chip).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI_bw

``cost_analysis()`` on the SPMD-partitioned module reports PER-DEVICE flops
and bytes; collective bytes are parsed from the compiled HLO text (operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 45e9            # effective bytes/s / chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[8,128]{1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        g = m.group(1)
        return max(len(g.split(",")) if g else 1, 1)
    return default


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Estimated per-device WIRE bytes of every collective, from the result
    shapes of the (per-device, scheduled) HLO. Ring-algorithm accounting:
      all-reduce      2 (g-1)/g * size      (size = result = operand)
      all-gather      (g-1)/g   * size      (result = gathered)
      reduce-scatter  (g-1)     * size      (result = scattered shard)
      all-to-all      (g-1)/g   * size
      collective-permute        size
    """
    out = {k: 0 for k in _COLLECTIVES}
    # "<name> = <shape|(tuple)> <op>(...), ..."
    op_re = re.compile(
        r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+(" + "|".join(_COLLECTIVES)
        + r")(?:-start)?\(")
    for line in hlo_text.splitlines():
        m = op_re.search(line)
        if not m:
            continue
        shapes, op = m.group(1), m.group(2)
        size = 0
        for sm in _SHAPE_RE.finditer(shapes):
            size += _shape_bytes(sm.group(1), sm.group(2))
        g = _group_size(line)
        if op == "all-reduce":
            wire = 2.0 * (g - 1) / g * size
        elif op in ("all-gather", "all-to-all"):
            wire = (g - 1) / g * size
        elif op == "reduce-scatter":
            wire = (g - 1) * size
        else:  # collective-permute
            wire = size
        out[op] += int(wire)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None
    xla_flops: Optional[float] = None   # raw cost_analysis (while body x1)
    xla_bytes: Optional[float] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, model_flops_per_device: Optional[float] = None,
            hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from the compiled module.

    FLOPs / HBM bytes / collective wire bytes come from the trip-count-aware
    HLO graph analyzer (hlo_analysis) — XLA's cost_analysis counts while
    bodies once, undercounting everything inside lax.scan. The raw XLA
    numbers are kept in ``xla_flops``/``xla_bytes`` for reference.
    """
    from .hlo_analysis import analyze_text

    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    totals = analyze_text(text)
    flops = max(totals.flops, xla_flops)
    byts = max(totals.memory_bytes, xla_bytes)
    coll = {k: int(v) for k, v in totals.coll.items()}
    coll_total = float(sum(coll.values()))
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": byts / HBM_BW,
        "collective": coll_total / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    useful = (model_flops_per_device / flops
              if model_flops_per_device and flops else None)
    return Roofline(
        flops=flops, bytes_accessed=byts, coll_bytes=coll,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], dominant=dominant,
        model_flops=model_flops_per_device, useful_ratio=useful,
        xla_flops=xla_flops, xla_bytes=xla_bytes)


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out
