"""Table II reproduction: suboptimality + speedup of the ADMM-based method
vs an exact ILP solver (HiGHS stands in for Gurobi).

Instances follow the paper's Scenario 1/2 construction for ResNet101/VGG19,
scaled down (coarser slots / fewer clients) so the exact solver terminates
on this 1-core container — the paper itself notes Gurobi needs hours at
J=20. Structure (device pools, cuts, delay synthesis) is identical.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import solve_admm, solve_exact, check_feasible
from repro.profiling.scenarios import cnn_instance, PAPER_SLOT_S


CASES = [
    # (model, scenario, J, I, slot multiplier vs paper's |S_t|)
    ("resnet101", 1, 5, 2, 8.0),
    ("resnet101", 1, 6, 3, 8.0),
    ("resnet101", 2, 5, 2, 8.0),
    ("vgg19", 1, 5, 2, 4.0),
    ("vgg19", 1, 6, 3, 4.0),
    ("vgg19", 2, 5, 2, 4.0),
]


def run(time_limit: float = 150.0, seed: int = 0):
    rows = []
    for model, sc, J, I, slot_mult in CASES:
        inst = cnn_instance(model, J=J, I=I, scenario=sc, seed=seed,
                            slot_s=PAPER_SLOT_S[model] * slot_mult)
        t0 = time.perf_counter()
        ex = solve_exact(inst, time_limit=time_limit, mip_rel_gap=1e-4)
        t_exact = time.perf_counter() - t0
        opt = ex.schedule.makespan(inst) if ex.schedule else float("nan")
        if ex.schedule is not None:
            check_feasible(inst, ex.schedule)
        t0 = time.perf_counter()
        admm = solve_admm(inst, mode="fast", tau_max=8)
        t_admm = time.perf_counter() - t0
        subopt = 100.0 * (admm.makespan - opt) / opt if opt == opt else float("nan")
        speedup = t_exact / max(t_admm, 1e-9)
        rows.append({
            "model": model, "scenario": sc, "J": J, "I": I, "T": inst.T,
            "exact_makespan": opt, "exact_status": ex.status,
            "exact_s": round(t_exact, 2),
            "admm_makespan": admm.makespan, "admm_s": round(t_admm, 3),
            "suboptimality_pct": round(subopt, 1),
            "speedup_x": round(speedup, 1),
        })
    return rows


def main():
    rows = run()
    print(f"{'model':10s} sc  J  I    T  exact  admm  subopt%  speedup")
    for r in rows:
        print(f"{r['model']:10s} {r['scenario']:2d} {r['J']:2d} {r['I']:2d} "
              f"{r['T']:4d} {r['exact_makespan']:6.0f} {r['admm_makespan']:5d} "
              f"{r['suboptimality_pct']:7.1f} {r['speedup_x']:8.1f}x"
              f"  ({r['exact_status']}, exact {r['exact_s']}s)")
    return rows


if __name__ == "__main__":
    main()
