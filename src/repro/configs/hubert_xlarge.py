"""HuBERT-XLarge [arXiv:2106.07447]: encoder-only transformer (w2v2 arch).

The mel-spectrogram + conv feature extractor frontend is a STUB per the task
spec: ``input_specs()`` provides precomputed frame embeddings (20ms frames).
Encoder-only: no autoregressive decode — decode shapes are skipped (DESIGN.md).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,  # masked-unit prediction codebook
    block_pattern=("attn",),
    mlp_kind="gelu",
    norm="layernorm",
    causal=False,
    frontend="audio",
    frontend_tokens=0,  # every position comes from the stub frontend
    tie_embeddings=False,
    sl_cut=(2, 46),
)
