"""Batched serving launcher: prefill + decode loop with KV/SSM caches.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b-smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import (Runtime, init_caches, init_params,
                                      serve_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{cfg.arch_id} is encoder-only: no decode")
    rt = Runtime()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    caches = init_caches(cfg, args.batch, max_len, rt, dtype=jnp.float32)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)

    step = jax.jit(lambda c, t, p: serve_step(cfg, params, c, t, p, rt))

    # prefill via token-by-token feed (keeps one compiled step; a production
    # deployment would use the prefill step from launch.steps)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, caches = step(caches, prompt[:, t:t + 1], jnp.int32(t))
    prefill_s = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits, caches = step(caches, tok, jnp.int32(args.prompt_len + i))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    decode_s = time.perf_counter() - t0
    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] {cfg.arch_id}: batch={args.batch} "
          f"prefill {args.prompt_len} tok in {prefill_s:.2f}s, "
          f"decoded {args.gen} tok in {decode_s:.2f}s "
          f"({args.batch * args.gen / max(decode_s, 1e-9):.1f} tok/s)")
    print("[serve] generated token ids (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
