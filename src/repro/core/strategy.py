"""Solution strategy (Observation 3, Sec. VII).

The paper's evaluations shape a strategy that picks the method by scenario
size and heterogeneity:

* small/medium + high heterogeneity -> ADMM-based method,
* large (>= ``large_j`` clients) or low heterogeneity at scale ->
  balanced-greedy (to avoid ADMM's overhead / bwd queueing pathologies).

We additionally expose the beyond-paper local-search refiner, which the
strategy applies when a time budget remains (off by default to stay
paper-faithful; ``refine=True`` enables it).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .admm import solve_admm
from .balanced_greedy import solve_balanced_greedy
from .instance import Instance
from .local_search import solve_local_search
from .schedule import Schedule


@dataclasses.dataclass
class StrategyResult:
    schedule: Schedule
    makespan: int
    method: str


def heterogeneity_score(inst: Instance) -> float:
    """Coefficient of variation of per-HELPER speed (isolates device
    heterogeneity from task-size variation by normalizing per client)."""
    p = inst.p.astype(float)
    pp = inst.pp.astype(float)
    ratios = np.concatenate([
        p / np.maximum(p.mean(axis=0, keepdims=True), 1e-9),
        pp / np.maximum(pp.mean(axis=0, keepdims=True), 1e-9),
    ], axis=1)  # [I, 2J]
    speed = ratios.mean(axis=1)
    return float(np.std(speed) / max(np.mean(speed), 1e-9))


def solve_strategy(
    inst: Instance,
    *,
    large_j: int = 60,
    het_threshold: float = 0.45,
    refine: bool = False,
    refine_budget_s: float = 10.0,
    admm_kwargs: Optional[dict] = None,
) -> StrategyResult:
    het = heterogeneity_score(inst)
    if inst.J >= large_j and het < het_threshold:
        res = solve_balanced_greedy(inst)
        sched, mk, method = res.schedule, res.makespan, "balanced-greedy"
    else:
        res = solve_admm(inst, **(admm_kwargs or {}))
        sched, mk, method = res.schedule, res.makespan, "admm"
        # cross-check against balanced-greedy; keep the better (paper's
        # strategy is scenario-conditional, this makes it instance-adaptive)
        g = solve_balanced_greedy(inst)
        if g.makespan < mk:
            sched, mk, method = g.schedule, g.makespan, "balanced-greedy"
    if refine:
        ls = solve_local_search(inst, init=sched.assign.copy(),
                                time_budget_s=refine_budget_s)
        if ls.makespan < mk:
            sched, mk, method = ls.schedule, ls.makespan, method + "+local-search"
    return StrategyResult(schedule=sched, makespan=mk, method=method)
