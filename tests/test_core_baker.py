"""Baker (1983) preemptive min-max-cost scheduler: optimality + invariants."""

import numpy as np
import pytest
from scipy import sparse
from scipy.optimize import Bounds, LinearConstraint, milp

from repro.core import baker


def _exact_single_machine(jobs, horizon, free=lambda t: True):
    """Reference ILP: min max_j (C_j + tail_j), preemptive, release dates."""
    n = len(jobs)
    T = horizon
    # vars: s[j, t] in {0,1}, phi[j], xi
    nvar = n * T + n + 1
    sidx = lambda j, t: j * T + t
    phi0 = n * T
    xi = n * T + n
    lb = np.zeros(nvar)
    ub = np.ones(nvar)
    ub[phi0:phi0 + n] = T
    ub[xi] = 2 * T
    integrality = np.concatenate([np.ones(n * T), np.zeros(n + 1)])
    c = np.zeros(nvar)
    c[xi] = 1.0
    rows, lo, hi = [], [], []

    def add(coefs, a, b):
        rows.append(coefs)
        lo.append(a)
        hi.append(b)

    for j, jb in enumerate(jobs):
        add({sidx(j, t): 1.0 for t in range(T)}, jb.proc, jb.proc)
        for t in range(min(jb.release, T)):
            ub[sidx(j, t)] = 0.0
        for t in range(T):
            if not free(t):
                ub[sidx(j, t)] = 0.0
            add({phi0 + j: 1.0, sidx(j, t): -(t + 1)}, 0.0, np.inf)
        add({xi: 1.0, phi0 + j: -1.0}, jb.tail, np.inf)
    for t in range(T):
        add({sidx(j, t): 1.0 for j in range(n)}, -np.inf, 1.0)

    data, ri, ci = [], [], []
    for rn, coefs in enumerate(rows):
        for k, v in coefs.items():
            ri.append(rn); ci.append(k); data.append(v)
    A = sparse.csr_matrix((data, (ri, ci)), shape=(len(rows), nvar))
    res = milp(c=c, constraints=LinearConstraint(A, np.array(lo), np.array(hi)),
               bounds=Bounds(lb, ub), integrality=integrality)
    assert res.x is not None, res.message
    return float(res.fun)


@pytest.mark.parametrize("seed", range(8))
def test_baker_matches_exact_ilp(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 6))
    jobs = [
        baker.Job(job_id=j, release=int(rng.integers(0, 6)),
                  proc=int(rng.integers(1, 5)), tail=int(rng.integers(0, 6)))
        for j in range(n)
    ]
    horizon = sum(j.proc for j in jobs) + max(j.release for j in jobs) + 1
    sol = baker.solve_min_max_cost(jobs, lambda t: True, horizon)
    got = baker.max_cost(jobs, sol)
    want = _exact_single_machine(jobs, horizon)
    assert got == pytest.approx(want), f"baker {got} != exact {want}"


@pytest.mark.parametrize("seed", range(4))
def test_baker_with_forbidden_slots_matches_exact(seed):
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(2, 5))
    jobs = [
        baker.Job(job_id=j, release=int(rng.integers(0, 4)),
                  proc=int(rng.integers(1, 4)), tail=int(rng.integers(0, 4)))
        for j in range(n)
    ]
    forbidden = set(int(t) for t in rng.choice(20, size=6, replace=False))
    free = lambda t: t not in forbidden
    horizon = 64
    sol = baker.solve_min_max_cost(jobs, free, horizon)
    # validity: no forbidden slots, no double-booking, releases respected
    seen = set()
    for jb in jobs:
        s = sol[jb.job_id]
        assert len(s) == jb.proc
        assert s[0] >= jb.release
        for t in s:
            assert free(int(t))
            assert int(t) not in seen
            seen.add(int(t))
    got = baker.max_cost(jobs, sol)
    want = _exact_single_machine(jobs, horizon, free)
    assert got == pytest.approx(want)


def test_baker_beats_or_ties_fcfs():
    rng = np.random.default_rng(5)
    for _ in range(20):
        n = int(rng.integers(2, 7))
        jobs = [
            baker.Job(job_id=j, release=int(rng.integers(0, 8)),
                      proc=int(rng.integers(1, 6)), tail=int(rng.integers(0, 8)))
            for j in range(n)
        ]
        horizon = sum(j.proc for j in jobs) + max(j.release for j in jobs) + 1
        pre = baker.solve_min_max_cost(jobs, lambda t: True, horizon)
        fcfs = baker.fcfs_nonpreemptive(jobs, lambda t: True, horizon)
        assert baker.max_cost(jobs, pre) <= baker.max_cost(jobs, fcfs)


def test_paper_worked_example_structure():
    """Fig. 4 family: one helper, 5 clients; checks block handling + optimality
    against the exact ILP on a structurally similar instance."""
    jobs = [
        baker.Job(job_id=1, release=0, proc=2, tail=5),
        baker.Job(job_id=4, release=1, proc=3, tail=1),
        baker.Job(job_id=2, release=3, proc=2, tail=3),
        baker.Job(job_id=3, release=6, proc=1, tail=8),
        baker.Job(job_id=5, release=9, proc=1, tail=2),
    ]
    horizon = 24
    sol = baker.solve_min_max_cost(jobs, lambda t: True, horizon)
    got = baker.max_cost(jobs, sol)
    want = _exact_single_machine(jobs, horizon)
    assert got == pytest.approx(want, abs=1e-4)
