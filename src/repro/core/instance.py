"""Problem instance for the joint assignment + scheduling problem (Sec. III).

All quantities follow the paper's notation:

* ``J`` clients, ``I`` helpers connected over a bipartite graph. We represent
  the edge set densely: a missing link is encoded with ``connected[i, j] =
  False`` (delays on missing links are ignored).
* Per-edge delay vectors (in integer time slots, see footnote 6):
    r[i, j]   client-side part-1 fwd + uplink of sigma1 activations
    p[i, j]   helper fwd-prop of part-2
    l[i, j]   downlink of sigma2 activations + client part-3 fwd + loss
    lp[i, j]  client part-3 bwd + uplink of sigma2 gradients      (l')
    pp[i, j]  helper bwd-prop of part-2                            (p')
    rp[i, j]  downlink of sigma1 gradients + client part-1 bwd     (r')
* d[j]  memory (GB) a helper must allocate for client j's part-2 task.
* m[i]  helper i memory capacity (GB).

The horizon T follows the paper:
  T = max_{(i,j) in E} (r + l + r' + l') + sum_j max_i (p + p').
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Instance:
    """One batch-makespan problem instance. Arrays indexed [i, j] (helper, client)."""

    r: np.ndarray
    p: np.ndarray
    l: np.ndarray
    lp: np.ndarray
    pp: np.ndarray
    rp: np.ndarray
    d: np.ndarray  # [J] memory demand per client task
    m: np.ndarray  # [I] memory capacity per helper
    connected: Optional[np.ndarray] = None  # [I, J] bool; None => complete bipartite
    mu: Optional[np.ndarray] = None  # [I] per-helper preemption (context switch) cost

    def __post_init__(self):
        for name in ("r", "p", "l", "lp", "pp", "rp"):
            a = getattr(self, name)
            if a.shape != (self.I, self.J):
                raise ValueError(f"{name} must have shape (I, J)={self.I, self.J}, got {a.shape}")
            if np.any(a < 0):
                raise ValueError(f"{name} must be non-negative")
            if not np.issubdtype(a.dtype, np.integer):
                raise ValueError(f"{name} must be integer slots (footnote 6); got {a.dtype}")
        if np.any(self.p <= 0) or np.any(self.pp <= 0):
            raise ValueError("helper processing times p, p' must be >= 1 slot")
        if self.connected is not None and self.connected.shape != (self.I, self.J):
            raise ValueError("connected must have shape (I, J)")

    @property
    def I(self) -> int:  # noqa: E743  (paper notation)
        return self.p.shape[0]

    @property
    def J(self) -> int:
        return self.p.shape[1]

    def edges(self):
        """Iterate (i, j) pairs in the edge set."""
        for i in range(self.I):
            for j in range(self.J):
                if self.is_edge(i, j):
                    yield i, j

    def is_edge(self, i: int, j: int) -> bool:
        return self.connected is None or bool(self.connected[i, j])

    def feasible_helpers(self, j: int) -> list[int]:
        return [i for i in range(self.I) if self.is_edge(i, j) and self.d[j] <= self.m[i]]

    # ---- time horizons -------------------------------------------------
    def _edge_mask(self) -> np.ndarray:
        if self.connected is None:
            return np.ones((self.I, self.J), dtype=bool)
        return self.connected.astype(bool)

    @property
    def T(self) -> int:
        """Upper bound on the batch makespan (Sec. III, Time Horizon)."""
        e = self._edge_mask()
        trans = int(np.max(np.where(e, self.r + self.l + self.rp + self.lp, 0)))
        proc = int(np.sum(np.max(np.where(e, self.p + self.pp, 0), axis=0)))
        return trans + proc

    @property
    def T_f(self) -> int:
        """Fwd-prop horizon T_f (Sec. V-A)."""
        e = self._edge_mask()
        trans = int(np.max(np.where(e, self.r + self.l, 0)))
        proc = int(np.sum(np.max(np.where(e, self.p, 0), axis=0)))
        return trans + proc

    # ---- sanity / feasibility ------------------------------------------
    def assert_assignable(self) -> None:
        """Quick check that a feasible assignment can exist (bin-packing relax)."""
        for j in range(self.J):
            if not self.feasible_helpers(j):
                raise ValueError(f"client {j} has no feasible helper (memory/connectivity)")

    def scaled(self, factor: float) -> "Instance":
        """Re-quantize all delays by ``factor`` (slot-length tuning, Sec. VII).

        ``factor > 1`` means *coarser* slots: delays shrink (ceil), preserving
        the paper's observation that larger |S_t| overestimates real durations
        less precisely but shrinks T.
        """
        def q(a):
            return np.maximum(np.ceil(a / factor), 0).astype(np.int64)

        def q1(a):  # processing times must stay >= 1
            return np.maximum(np.ceil(a / factor), 1).astype(np.int64)

        return Instance(
            r=q(self.r), p=q1(self.p), l=q(self.l), lp=q(self.lp),
            pp=q1(self.pp), rp=q(self.rp), d=self.d.copy(), m=self.m.copy(),
            connected=None if self.connected is None else self.connected.copy(),
            mu=None if self.mu is None else self.mu.copy(),
        )


def random_instance(
    J: int,
    I: int,
    *,
    seed: int = 0,
    r_range=(1, 8),
    p_range=(1, 10),
    l_range=(1, 6),
    lp_range=(1, 6),
    pp_range=(1, 14),
    rp_range=(1, 8),
    mem_tight: float = 2.0,
    heterogeneity: float = 1.0,
) -> Instance:
    """Synthetic instance generator (used by tests & hypothesis strategies).

    ``heterogeneity`` scales the spread of per-helper speeds, mirroring the
    paper's Scenario 1 (low) vs Scenario 2 (high).
    """
    rng = np.random.default_rng(seed)

    def draw(rg, row_speed=None):
        lo, hi = rg
        base = rng.integers(lo, hi + 1, size=(I, J)).astype(np.int64)
        if row_speed is not None:
            base = np.maximum(1, np.round(base * row_speed[:, None])).astype(np.int64)
        return base

    # helper speed multipliers: heterogeneity stretches the spread
    speed = np.exp(rng.normal(0.0, 0.35 * heterogeneity, size=I))
    r = draw(r_range)
    p = draw(p_range, speed)
    l = draw(l_range)
    lp = draw(lp_range)
    pp = draw(pp_range, speed)
    rp = draw(rp_range)
    d = rng.uniform(0.5, 1.5, size=J)
    # total capacity ~= mem_tight * total demand, split across helpers
    cap = mem_tight * d.sum() / I
    m = rng.uniform(0.8 * cap, 1.2 * cap, size=I)
    # guarantee feasibility: the largest helper can hold the largest task
    m[int(np.argmax(m))] = max(m.max(), d.max() * 1.01)
    inst = Instance(r=r, p=p, l=l, lp=lp, pp=pp, rp=rp, d=d, m=m)
    inst.assert_assignable()
    return inst
