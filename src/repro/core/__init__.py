"""Core library: joint client-helper assignment + scheduling for parallel SL.

Implements the INFOCOM'24 paper "Workflow Optimization for Parallel Split
Learning" — Problem 1 (exact MILP), the ADMM decomposition (Algorithm 1),
the optimal bwd-prop scheduler (Algorithm 2 / Theorem 2), the
balanced-greedy heuristic, the random+FCFS baseline, the preemption-cost
extension, and the scenario-adaptive solution strategy.
"""

from .instance import Instance, random_instance
from .schedule import (Schedule, check_feasible, InfeasibleScheduleError,
                       lower_bound, queuing_delay)
from .baker import Job, solve_min_max_cost, fcfs_nonpreemptive, max_cost
from .bwd_schedule import (schedule_bwd, schedule_fwd_given_assignment,
                           full_schedule_for_assignment)
from .admm import solve_admm, AdmmResult
from .balanced_greedy import solve_balanced_greedy, assign_balanced, \
    schedule_fcfs, GreedyResult
from .baseline import solve_baseline, assign_random, BaselineResult
from .local_search import solve_local_search, LocalSearchResult
from .strategy import solve_strategy, StrategyResult, heterogeneity_score
from .milp import solve_exact, MilpResult
from .cut_search import search_cuts, candidate_cuts, CutSearchResult
from .pipeline import schedule_pipelined, PipelineResult

__all__ = [
    "Instance", "random_instance", "Schedule", "check_feasible",
    "InfeasibleScheduleError", "lower_bound", "queuing_delay",
    "Job", "solve_min_max_cost", "fcfs_nonpreemptive", "max_cost",
    "schedule_bwd", "schedule_fwd_given_assignment",
    "full_schedule_for_assignment",
    "solve_admm", "AdmmResult",
    "solve_balanced_greedy", "assign_balanced", "schedule_fcfs", "GreedyResult",
    "solve_baseline", "assign_random", "BaselineResult",
    "solve_local_search", "LocalSearchResult",
    "solve_strategy", "StrategyResult", "heterogeneity_score",
    "solve_exact", "MilpResult",
    "search_cuts", "candidate_cuts", "CutSearchResult",
    "schedule_pipelined", "PipelineResult",
]
