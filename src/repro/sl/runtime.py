"""Parallel split-learning runtime: the system whose workflow the paper
optimizes, executed for real in JAX.

Entities (all logical on this host, each owning ONLY its own parameters):
  * clients j: part-1 + part-3 params, local optimizer, local dataset shard;
  * helpers i: one part-2 copy PER assigned client (parallel SL), its own
    optimizer per copy;
  * aggregator: FedAvg over all part copies at the end of each round.

Each batch update follows Fig. 2: part-1 fwd at the client, activations to
the helper, part-2 fwd, part-3 fwd + loss at the client, then the backward
chain — gradients cross the cuts exactly as they would on the wire
(``models.split.sl_batch_grads``). Simulated wall-clock comes from the
schedule produced by the core optimizers; compute is real.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.instance import Instance
from repro.core.schedule import Schedule
from repro.models.split import sl_batch_grads, split_params
from repro.models.transformer import Runtime, init_params
from repro.optim.adam import Adam
from .fedavg import fedavg
from .simulator import simulate


@dataclasses.dataclass
class RoundStats:
    round_idx: int
    mean_loss: float
    batch_makespan_slots: int
    simulated_time_slots: int
    cut_traffic_bytes: int


class ParallelSLTrainer:
    """J clients, I helpers, one global model trained with parallel SL."""

    def __init__(self, cfg: ModelConfig, inst: Instance, sched: Schedule,
                 *, lr: float = 3e-3, seed: int = 0,
                 rt: Optional[Runtime] = None):
        assert inst.J == len(sched.assign)
        self.cfg, self.inst, self.sched = cfg, inst, sched
        self.rt = rt or Runtime()
        key = jax.random.PRNGKey(seed)
        global_params = init_params(cfg, key)
        spec, p1, p2, p3 = split_params(cfg, global_params)
        self.spec = spec
        self.opt = Adam(lr=lr)
        # per-client copies (parallel SL: every client trains its own version)
        self.client_p1 = [jax.tree.map(jnp.copy, p1) for _ in range(inst.J)]
        self.client_p3 = [jax.tree.map(jnp.copy, p3) for _ in range(inst.J)]
        self.helper_p2 = [jax.tree.map(jnp.copy, p2) for _ in range(inst.J)]
        self.opt1 = [self.opt.init(p) for p in self.client_p1]
        self.opt3 = [self.opt.init(p) for p in self.client_p3]
        self.opt2 = [self.opt.init(p) for p in self.helper_p2]
        self._grad_fn = jax.jit(
            lambda p1_, p2_, p3_, b: sl_batch_grads(cfg, spec, p1_, p2_, p3_,
                                                    b, self.rt))
        self.round_idx = 0

    # ------------------------------------------------------------------
    def run_round(self, client_batches: List[Dict[str, np.ndarray]],
                  *, local_steps: int = 1) -> RoundStats:
        """One training round (global epoch): ``local_steps`` batch updates
        per client, then FedAvg aggregation of every part."""
        losses = []
        traffic = 0
        for _ in range(local_steps):
            # helpers process their clients in the schedule's order; compute
            # results are order-independent, time comes from the schedule
            for j in range(self.inst.J):
                batch = {k: jnp.asarray(v) for k, v in client_batches[j].items()}
                loss, g1, g2, g3, tr = self._grad_fn(
                    self.client_p1[j], self.helper_p2[j],
                    self.client_p3[j], batch)
                self.client_p1[j], self.opt1[j] = self.opt.update(
                    g1, self.opt1[j], self.client_p1[j])
                self.helper_p2[j], self.opt2[j] = self.opt.update(
                    g2, self.opt2[j], self.helper_p2[j])
                self.client_p3[j], self.opt3[j] = self.opt.update(
                    g3, self.opt3[j], self.client_p3[j])
                losses.append(float(loss))
                traffic += int(tr["cut1_bytes"] + tr["cut2_bytes"]) * 2
        # ---- aggregation (FedAvg) over all versions ----------------------
        p1 = fedavg(self.client_p1)
        p3 = fedavg(self.client_p3)
        p2 = fedavg(self.helper_p2)
        self.client_p1 = [jax.tree.map(jnp.copy, p1) for _ in range(self.inst.J)]
        self.client_p3 = [jax.tree.map(jnp.copy, p3) for _ in range(self.inst.J)]
        self.helper_p2 = [jax.tree.map(jnp.copy, p2) for _ in range(self.inst.J)]
        mk = self.sched.makespan(self.inst)
        self.round_idx += 1
        return RoundStats(
            round_idx=self.round_idx,
            mean_loss=float(np.mean(losses)),
            batch_makespan_slots=mk,
            simulated_time_slots=mk * local_steps,
            cut_traffic_bytes=traffic,
        )

    # ------------------------------------------------------------------
    def eval_loss(self, batch: Dict[str, np.ndarray], client: int = 0) -> float:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, *_ = self._grad_fn(self.client_p1[client],
                                 self.helper_p2[client],
                                 self.client_p3[client], batch)
        return float(loss)

    def report(self):
        return simulate(self.inst, self.sched)
