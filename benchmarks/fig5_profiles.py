"""Fig. 5 reproduction: profiled part-1 computing time per device, plus a
calibration check that per-device batch times reproduce Table I."""

from __future__ import annotations

from repro.profiling.devices import DEVICES
from repro.profiling.scenarios import _bwd_frac, _cnn_part_times, _device_time
from repro.profiling.testbed_models import TESTBED_MODELS


def run():
    rows = []
    for model, tm in TESTBED_MODELS.items():
        cut = tm.default_cut
        bwd = _bwd_frac(model)
        for dev_key in ("rpi4", "rpi3", "jetson_cpu", "jetson_gpu", "vm8", "m1"):
            dev = DEVICES[dev_key]
            total = _device_time(dev, model)
            fw = _cnn_part_times(tm, total, cut, bwd)
            rows.append({
                "model": model, "device": dev_key,
                "batch_time_s": round(total, 2),
                "table1_s": (dev.table1 or {}).get(model),
                "part1_fwd_ms": round(fw[0] * 1000, 1),
                "part2_fwd_ms": round(fw[1] * 1000, 1),
                "part3_fwd_ms": round(fw[2] * 1000, 1),
                "bwd_over_fwd": bwd,
            })
    return rows


def main():
    rows = run()
    print(f"{'model':10s} {'device':11s} batch_s  table1  p1_fwd_ms p2_fwd_ms p3_fwd_ms")
    for r in rows:
        t1 = f"{r['table1_s']:.1f}" if r["table1_s"] else "   -"
        print(f"{r['model']:10s} {r['device']:11s} {r['batch_time_s']:7.2f} "
              f"{t1:>7s} {r['part1_fwd_ms']:9.1f} {r['part2_fwd_ms']:9.1f} "
              f"{r['part3_fwd_ms']:9.1f}")
    # calibration: devices WITH measurements must match Table I exactly
    for r in rows:
        if r["table1_s"]:
            assert abs(r["batch_time_s"] - r["table1_s"]) < 0.05, r
    return rows


if __name__ == "__main__":
    main()
