"""Algorithm 2 — optimal bwd-prop schedule (Theorem 2), plus the analogous
fwd-prop scheduler for a fixed assignment.

Both are instances of preemptive single-machine min-max-cost scheduling with
release dates (Baker et al. 1983), solved per helper in parallel:

* bwd-prop (P_b^i): job j released at ``phi^f_j + l_j + l'_j`` (gradients
  arrive at helper), proc ``p'_j``, cost ``phi_j + r'_j``. The machine is only
  available on slots the fwd schedule left free.
* fwd-prop given y (used by the fast ADMM w-step and local search): job j
  released at ``r_j``, proc ``p_j``, cost ``phi^f_j + l_j``.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from . import baker
from .instance import Instance
from .schedule import Schedule


def schedule_bwd(inst: Instance, sched: Schedule, *, horizon: Optional[int] = None) -> Schedule:
    """Fill in z (bwd-prop) optimally given assignment + fwd schedule (Alg. 2)."""
    T = int(horizon if horizon is not None else inst.T)
    z_slots: List[np.ndarray] = [np.array([], dtype=np.int64)] * inst.J
    for i in range(inst.I):
        clients = [j for j in range(inst.J) if int(sched.assign[j]) == i]
        if not clients:
            continue
        occupied = set()
        for j in clients:
            occupied.update(int(t) for t in sched.x_slots[j])
        jobs = []
        for j in clients:
            release = sched.phi_f(j) + int(inst.l[i, j]) + int(inst.lp[i, j])
            jobs.append(baker.Job(job_id=j, release=release,
                                  proc=int(inst.pp[i, j]), tail=int(inst.rp[i, j])))
        sol = baker.solve_min_max_cost(jobs, lambda t: t not in occupied, T)
        for j in clients:
            z_slots[j] = sol[j]
    return Schedule(assign=sched.assign.copy(),
                    x_slots=[s.copy() for s in sched.x_slots],
                    z_slots=z_slots)


def schedule_fwd_given_assignment(
    inst: Instance, assign: np.ndarray, *, horizon: Optional[int] = None
) -> Schedule:
    """Optimal preemptive fwd schedule per helper for a fixed assignment.

    Minimizes max_j c^f_j = phi^f_j + l_j per helper, which is exactly the
    Baker problem with tail = l_j.
    """
    T = int(horizon if horizon is not None else inst.T)
    x_slots: List[np.ndarray] = [np.array([], dtype=np.int64)] * inst.J
    for i in range(inst.I):
        clients = [j for j in range(inst.J) if int(assign[j]) == i]
        if not clients:
            continue
        jobs = [
            baker.Job(job_id=j, release=int(inst.r[i, j]),
                      proc=int(inst.p[i, j]), tail=int(inst.l[i, j]))
            for j in clients
        ]
        sol = baker.solve_min_max_cost(jobs, lambda t: True, T)
        for j in clients:
            x_slots[j] = sol[j]
    return Schedule(assign=np.asarray(assign, dtype=np.int64).copy(),
                    x_slots=x_slots,
                    z_slots=[np.array([], dtype=np.int64)] * inst.J)


def full_schedule_for_assignment(
    inst: Instance, assign: np.ndarray, *, horizon: Optional[int] = None
) -> Schedule:
    """Optimal-fwd (Baker) then optimal-bwd (Alg. 2) for a fixed assignment."""
    fwd = schedule_fwd_given_assignment(inst, assign, horizon=horizon)
    return schedule_bwd(inst, fwd, horizon=horizon)
