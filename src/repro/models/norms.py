"""Normalization layers (pure functions over param dicts)."""

from __future__ import annotations

import jax.numpy as jnp


def rmsnorm(x, scale, *, eps: float = 1e-6, gemma_style: bool = True):
    """RMSNorm. ``gemma_style`` uses (1 + scale) parameterization."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * (var + eps) ** -0.5
    w = (1.0 + scale.astype(jnp.float32)) if gemma_style else scale.astype(jnp.float32)
    return (y * w).astype(dtype)


def layernorm(x, scale, bias, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * (var + eps) ** -0.5
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x, params: dict, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])
