"""Algorithm 1 — ADMM-based fwd-prop workflow optimization (Sec. V-A).

The augmented Lagrangian (16) relaxes the coupling constraints (6) with an
l1 penalty. Each iteration:

  line 2  w-step: schedule (x, phi^f, c^f) given (y, lambda)
  line 3  y-step: assignment given the new schedule
  line 4  dual update on the violation of (6)
  line 5  convergence flags (17), (18)
  line 6  feasibility correction (19)

Two w-step solvers are provided:
  * ``mode="milp"``  — exact ILP via HiGHS (the paper's configuration;
    footnote 7's "exact methods").
  * ``mode="fast"``  — inexact: a load/penalty-aware helper choice followed by
    an optimal per-helper preemptive schedule (Baker). Footnote 7 explicitly
    allows inexact subproblem solutions; this is what scales.

The y-step is a small generalized-assignment MILP (exact in both modes).
After convergence, the bwd-prop schedule is completed with Algorithm 2.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from . import baker, milp
from .bwd_schedule import full_schedule_for_assignment, schedule_bwd, \
    schedule_fwd_given_assignment
from .instance import Instance
from .schedule import Schedule, check_feasible


@dataclasses.dataclass
class AdmmResult:
    schedule: Schedule
    makespan: int
    fwd_makespan: int
    iterations: int
    converged: bool
    runtime_s: float
    history: List[dict]


def _x_totals(inst: Instance, sched: Schedule) -> np.ndarray:
    X = np.zeros((inst.I, inst.J))
    for j in range(inst.J):
        X[int(sched.assign[j]), j] = len(sched.x_slots[j])
    return X


def _fast_w_step(inst: Instance, y: np.ndarray, lam: np.ndarray, rho: float,
                 horizon: int) -> Schedule:
    """Inexact w-step: penalty-aware helper choice + optimal Baker schedules.

    Under constraint (20) each client is fully processed on one helper h;
    choosing h != argmax(y[:, j]) incurs the l1 penalty rho/2 (p_hj + p_yj)
    plus the lagrangian term lam_hj p_hj (see milp.solve_y_subproblem docs
    for the symmetric y-step derivation).
    """
    load = np.zeros(inst.I)
    choice = np.full(inst.J, -1, dtype=np.int64)
    # clients with larger tasks choose first (LPT-style)
    order = sorted(range(inst.J),
                   key=lambda j: -float(np.mean([inst.p[i, j] for i in range(inst.I)
                                                 if inst.is_edge(i, j)])))
    for j in order:
        y_j = int(np.argmax(y[:, j])) if y[:, j].max() > 0 else -1
        best, best_score = None, np.inf
        for h in range(inst.I):
            if not inst.is_edge(h, j):
                continue
            pen = float(lam[h, j]) * float(inst.p[h, j])
            if y_j >= 0 and h != y_j:
                pen += (rho / 2.0) * (float(inst.p[h, j]) + float(inst.p[y_j, j]))
            elif y_j < 0:
                pen += (rho / 2.0) * float(inst.p[h, j])
            est = max(float(inst.r[h, j]), load[h]) + float(inst.p[h, j]) \
                + float(inst.l[h, j])
            score = est + pen
            if score < best_score:
                best, best_score = h, score
        choice[j] = best
        load[best] += float(inst.p[best, j])
    return schedule_fwd_given_assignment(inst, choice, horizon=horizon)


def solve_admm(
    inst: Instance,
    *,
    rho: float = 1.0,
    tau_max: int = 10,
    eps1: float = 0.5,
    eps2: float = 0.5,
    mode: str = "fast",
    w_time_limit: Optional[float] = 20.0,
    track_best: bool = True,
    horizon: Optional[int] = None,
    verbose: bool = False,
) -> AdmmResult:
    """Run Algorithm 1 + Algorithm 2 and return a full feasible schedule."""
    t0 = time.perf_counter()
    T = int(horizon if horizon is not None else inst.T)
    Tf = inst.T_f
    lam = np.zeros((inst.I, inst.J))
    y = np.zeros((inst.I, inst.J), dtype=np.int64)  # y^(0) = 0 (Alg. 1 input)
    prev_cf = None
    history: List[dict] = []
    best_sched, best_mk = None, np.inf
    converged = False
    it = 0

    for it in range(1, tau_max + 1):
        # ---- line 2: w-step -------------------------------------------
        if mode == "milp":
            w_sched, _ = milp.solve_w_subproblem(
                inst, y, lam, rho, time_limit=w_time_limit, horizon=Tf)
        else:
            w_sched = _fast_w_step(inst, y, lam, rho, Tf)
        X = _x_totals(inst, w_sched)
        # ---- line 3: y-step -------------------------------------------
        y_new = milp.solve_y_subproblem(inst, X, lam, rho)
        # ---- line 4: dual update --------------------------------------
        viol = X - y_new * inst.p
        lam = lam + viol
        cf = w_sched.fwd_makespan(inst)
        dy = int(np.abs(y_new - y).sum())
        history.append({"iter": it, "fwd_makespan": cf, "dy": dy,
                        "violation_l1": float(np.abs(viol).sum())})
        if verbose:
            print(f"[admm] it={it} cf={cf} dy={dy} "
                  f"viol={float(np.abs(viol).sum()):.1f}")
        y = y_new
        if track_best:
            cand = full_schedule_for_assignment(
                inst, np.argmax(y, axis=0), horizon=T)
            mk = cand.makespan(inst)
            if mk < best_mk:
                best_sched, best_mk = cand, mk
        # ---- line 5: convergence flags (17), (18) ----------------------
        if prev_cf is not None and dy < eps1 and abs(cf - prev_cf) < eps2:
            converged = True
            break
        prev_cf = cf

    # ---- line 6: correction (19) — schedule consistent with y* --------
    assign = np.argmax(y, axis=0)
    final = full_schedule_for_assignment(inst, assign, horizon=T)
    if track_best and best_sched is not None and best_mk < final.makespan(inst):
        final = best_sched
    check_feasible(inst, final, horizon=T)
    return AdmmResult(
        schedule=final,
        makespan=final.makespan(inst),
        fwd_makespan=final.fwd_makespan(inst),
        iterations=it,
        converged=converged,
        runtime_s=time.perf_counter() - t0,
        history=history,
    )
