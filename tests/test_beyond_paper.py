"""Beyond-paper extensions: cut-layer co-optimization + batch pipelining."""

import numpy as np
import pytest

from repro.core import (check_feasible, schedule_pipelined, search_cuts,
                        solve_balanced_greedy)
from repro.core.balanced_greedy import assign_balanced
from repro.core.cut_search import candidate_cuts
from repro.profiling.scenarios import cnn_instance, instance_builder_for
from repro.profiling.testbed_models import TESTBED_MODELS


def test_candidate_cuts_keep_part2_dominant():
    for L in (25, 37, 61):
        for s1, s2 in candidate_cuts(L):
            assert 0 <= s1 < s2 <= L
            assert (s2 - s1) >= L // 2


def test_cut_search_improves_fixed_cut():
    model = "resnet101"
    J, I = 8, 2
    builder = instance_builder_for(model, J, I, seed=0)
    tm = TESTBED_MODELS[model]
    fixed = builder([tm.default_cut] * J)
    base = solve_balanced_greedy(fixed).makespan
    res = search_cuts(builder, tm.num_layers, J, init_cut=tm.default_cut,
                      rounds=1, stride=4)
    check_feasible(res.instance, res.schedule)
    assert res.makespan <= base
    assert len(res.cuts) == J
    # monotone improvement across rounds
    mks = [h["makespan"] for h in res.history]
    assert mks == sorted(mks, reverse=True)


def test_pipelining_beats_sequential():
    inst = cnn_instance("vgg19", J=10, I=3, scenario=2, seed=1)
    assign = assign_balanced(inst)
    res = schedule_pipelined(inst, assign, K=4)
    assert res.makespan < res.sequential_makespan
    assert res.gain_pct > 10.0
    # batch completions are ordered
    pb = res.per_batch_completion
    assert pb == sorted(pb)


def test_pipelining_k1_consistency():
    inst = cnn_instance("resnet101", J=6, I=2, scenario=1, seed=2)
    assign = assign_balanced(inst)
    res = schedule_pipelined(inst, assign, K=1)
    assert res.makespan == res.sequential_makespan
    assert res.gain_pct == 0.0
    # list scheduler never beats the per-client critical path
    i0 = int(assign[0])
    path = int(inst.r[i0, 0] + inst.p[i0, 0] + inst.l[i0, 0]
               + inst.lp[i0, 0] + inst.pp[i0, 0] + inst.rp[i0, 0])
    assert res.makespan >= path
