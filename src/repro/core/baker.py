"""Preemptive single-machine min-max-cost scheduling (Baker et al., 1983).

This is the engine behind Theorem 2 / Algorithm 2: minimizing
``max_j (C_j + pi_j)`` on one machine with release dates and preemption is
polynomially solvable. We implement the block-decomposition algorithm of
Baker, Lawler, Lenstra & Rinnooy Kan, generalized to a machine that is only
available on a given subset of time slots (needed because bwd-prop tasks may
only use the slots the fwd-prop schedule left free, Sec. V-B).

Jobs are ``(job_id, release, proc, tail)`` with cost(C) = C + tail, which is
nondecreasing in C as the theorem requires. ``tail`` is the paper's
``pi_j = r'_{ij}`` for bwd-prop, or ``l_{ij}`` when reused for fwd-prop.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Job:
    job_id: int
    release: int
    proc: int
    tail: int

    def cost(self, completion: int) -> int:
        return completion + self.tail


def _form_blocks(jobs: Sequence[Job], slots: Sequence[int]) -> List[tuple]:
    """Greedy sweep over available slots; returns [(block_jobs, block_slots)].

    A block is a maximal busy period: the machine never idles on an available
    slot while a released, unfinished job exists.
    """
    jobs_by_release = sorted(jobs, key=lambda jb: (jb.release, jb.job_id))
    n = len(jobs_by_release)
    remaining = {jb.job_id: jb.proc for jb in jobs_by_release}
    nxt = 0  # next job (by release) not yet added to the pool
    pool: List[Job] = []
    blocks: List[tuple] = []
    cur_jobs: List[Job] = []
    cur_slots: List[int] = []
    done = 0
    for t in slots:
        while nxt < n and jobs_by_release[nxt].release <= t:
            pool.append(jobs_by_release[nxt])
            nxt += 1
        if not pool:
            if cur_slots:
                blocks.append((cur_jobs, cur_slots))
                cur_jobs, cur_slots = [], []
            continue
        jb = pool[0]
        if jb not in cur_jobs:
            cur_jobs.append(jb)
        remaining[jb.job_id] -= 1
        cur_slots.append(t)
        if remaining[jb.job_id] == 0:
            pool.pop(0)
            done += 1
            if done == n and not pool:
                # flush any pool-mates first (pool is empty here)
                pass
        if done == n:
            break
    if cur_slots:
        blocks.append((cur_jobs, cur_slots))
    total = sum(len(s) for _, s in blocks)
    need = sum(jb.proc for jb in jobs)
    if total != need:
        raise ValueError(
            f"not enough available slots to complete all jobs ({total} < {need})")
    # blocks may have accumulated jobs whose slots spilled into later sweeps;
    # recompute job membership per block from slot ownership is not needed:
    # the greedy sweep never leaves a job unfinished at a block boundary.
    return blocks


def _solve_block(jobs: List[Job], slots: List[int], out: Dict[int, List[int]]) -> None:
    """Recursive step: pick l = argmin cost at block end, recurse on the rest."""
    if not jobs:
        return
    if len(jobs) == 1:
        jb = jobs[0]
        usable = [t for t in slots if t >= jb.release][: jb.proc]
        if len(usable) < jb.proc:
            raise ValueError("block slots insufficient for single job")
        out[jb.job_id].extend(usable)
        return
    end = slots[-1] + 1  # e(beta)
    ell = min(jobs, key=lambda jb: (jb.cost(end), jb.job_id))
    rest = [jb for jb in jobs if jb.job_id != ell.job_id]
    # recursively schedule the rest inside this block's slots; they decompose
    # into subblocks on their own (the recursive _form_blocks handles it)
    sub_blocks = _form_blocks(rest, slots)
    used: set = set()
    for bj, bs in sub_blocks:
        _solve_block(bj, bs, out)
    for jb in rest:
        used.update(out_slots_of(out, jb.job_id, jb.proc))
    leftover = [t for t in slots if t not in used and t >= ell.release]
    if len(leftover) < ell.proc:
        raise ValueError("leftover slots insufficient for selected job l")
    out[ell.job_id].extend(leftover[: ell.proc])


def out_slots_of(out: Dict[int, List[int]], job_id: int, proc: int) -> List[int]:
    s = out[job_id]
    return s[-proc:] if len(s) >= proc else s


def solve_min_max_cost(
    jobs: Iterable[Job],
    slot_free: Callable[[int], bool],
    horizon: int,
) -> Dict[int, np.ndarray]:
    """Optimal preemptive schedule minimizing max_j (C_j + tail_j).

    ``slot_free(t)`` says whether the machine is available in slot ``t``;
    slots are searched in ``[0, horizon)``. Returns job_id -> sorted slots.
    """
    jobs = list(jobs)
    if not jobs:
        return {}
    need = sum(jb.proc for jb in jobs)
    slots: List[int] = []
    min_rel = min(jb.release for jb in jobs)
    t = min_rel
    # Collect enough free slots: conservatively keep sweeping until, simulating
    # the greedy, all jobs can complete.
    while t < horizon and len(slots) < need + (horizon - min_rel):
        if slot_free(t):
            slots.append(t)
        t += 1
        if len(slots) >= need and slots and slots[-1] >= max(jb.release for jb in jobs):
            # enough capacity after the last release: greedy can always finish
            after_last = sum(1 for s in slots if s >= max(jb.release for jb in jobs))
            if after_last >= need:
                break
    out: Dict[int, List[int]] = {jb.job_id: [] for jb in jobs}
    for bj, bs in _form_blocks(jobs, slots):
        _solve_block(list(bj), list(bs), out)
    result = {}
    for jb in jobs:
        arr = np.array(sorted(out[jb.job_id]), dtype=np.int64)
        if len(arr) != jb.proc:
            raise AssertionError(
                f"job {jb.job_id}: scheduled {len(arr)} != proc {jb.proc}")
        result[jb.job_id] = arr
    return result


def fcfs_nonpreemptive(
    jobs: Iterable[Job],
    slot_free: Callable[[int], bool],
    horizon: int,
) -> Dict[int, np.ndarray]:
    """Non-preemptive FCFS by release time (balanced-greedy / baseline schedule).

    When the machine frees up, it takes the earliest-released waiting job and
    runs it to completion on the next ``proc`` *available* slots.
    """
    order = sorted(jobs, key=lambda jb: (jb.release, jb.job_id))
    out: Dict[int, np.ndarray] = {}
    t = 0
    for jb in order:
        t = max(t, jb.release)
        slots = []
        while len(slots) < jb.proc:
            if t >= horizon:
                raise ValueError("horizon too small for FCFS schedule")
            if slot_free(t):
                slots.append(t)
            t += 1
        out[jb.job_id] = np.array(slots, dtype=np.int64)
    return out


def max_cost(jobs: Iterable[Job], sched: Dict[int, np.ndarray]) -> int:
    return max(jb.cost(int(sched[jb.job_id][-1]) + 1) for jb in jobs)
