"""Production meshes + sharding rules.

Target: TPU v5e. Single pod = 16x16 = 256 chips (axes data x model);
multi-pod = 2 pods = 512 chips (axes pod x data x model). Functions, not
module constants, so importing never touches jax device state.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def fsdp_axes(multi_pod: bool) -> Tuple[str, ...]:
    """Axes over which batch + fsdp-sharded params are split."""
    return ("pod", "data") if multi_pod else ("data",)


def sharding_rules(multi_pod: bool) -> Dict[str, object]:
    """Logical axis -> mesh axis (or tuple). The default scheme:
    tensor-parallel over 'model', FSDP over 'data' (+'pod')."""
    fsdp = fsdp_axes(multi_pod)
    return {
        "vocab": "model",
        "embed": fsdp,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",
        # baseline: expert weights ALSO fsdp-sharded over data (ZeRO-style
        # storage; gathered at use). The perf iteration flips this to None
        # (experts sharded over model only -> no per-layer gather).
        "moe_embed": fsdp,
        "moe_mlp": None,
        "layers": None,
    }


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def auto_pspec(shape: Tuple[int, ...], wanted, mesh: Mesh) -> P:
    """Build a PartitionSpec, dropping axes that do not divide the dim and
    deduplicating mesh axes used twice (first dim wins)."""
    used = set()
    out = []
    for dim, ax in zip(shape, wanted):
        if ax is None:
            out.append(None)
            continue
        axes = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
        axes = tuple(a for a in axes if a not in used)
        if not axes or dim % _axis_size(mesh, axes) != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def param_shardings(cfg, mesh: Mesh, *, rules: Optional[Dict] = None):
    """NamedSharding tree for a ModelConfig's parameters on ``mesh``."""
    from repro.models.transformer import Spec, model_plan

    multi_pod = "pod" in mesh.axis_names
    rules = rules if rules is not None else sharding_rules(multi_pod)

    def f(s: Spec):
        wanted = [rules.get(a) if a else None for a in s.axes]
        return NamedSharding(mesh, auto_pspec(s.shape, wanted, mesh))

    return jax.tree.map(f, model_plan(cfg),
                        is_leaf=lambda x: isinstance(x, Spec))


def batch_sharding(mesh: Mesh):
    """Batch-dim sharding for input arrays [B, ...]."""
    multi_pod = "pod" in mesh.axis_names
    fsdp = fsdp_axes(multi_pod)
    def f(ndim: int) -> NamedSharding:
        return NamedSharding(mesh, P(fsdp, *([None] * (ndim - 1))))
    return f
