"""Beyond-paper: joint cut-layer + assignment + scheduling optimization.

The paper is "oblivious to the cut layers, which are decided in advance"
and names per-client cut selection as future work (Sec. VIII). This module
closes that loop: given the architecture's analytic cost model and the
device/link catalog, it searches per-client cuts (sigma_1, sigma_2) jointly
with the workflow optimization:

  outer loop   coordinate descent over per-client cuts (candidate grid from
               the cost model: cuts that keep part-2 dominant and the cut
               tensors small),
  inner loop   the paper's machinery — assignment + optimal preemptive
               scheduling (Baker fwd + Algorithm 2 bwd) — evaluates each
               candidate exactly.

This typically beats any fixed-cut configuration because slow clients get
thinner parts 1/3 while fast clients keep more layers local.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bwd_schedule import full_schedule_for_assignment
from repro.core.balanced_greedy import assign_balanced
from repro.core.instance import Instance
from repro.core.schedule import Schedule, check_feasible


@dataclasses.dataclass
class CutSearchResult:
    cuts: List[Tuple[int, int]]
    schedule: Schedule
    instance: Instance
    makespan: int
    evaluations: int
    history: List[dict]


def candidate_cuts(num_layers: int, *, max_client_layers: int = None,
                   stride: int = 1) -> List[Tuple[int, int]]:
    """Cut grid keeping part-2 the largest part (the SL premise)."""
    L = num_layers
    lim = max_client_layers if max_client_layers is not None else max(2, L // 4)
    out = []
    for s1 in range(0, lim + 1, stride):
        for tail in range(0, lim + 1 - s1, stride):
            s2 = L - tail
            if s2 - s1 >= max(1, L // 2):
                out.append((s1, s2))
    return out


def search_cuts(
    instance_builder: Callable[[Sequence[Tuple[int, int]]], Instance],
    num_layers: int,
    J: int,
    *,
    init_cut: Optional[Tuple[int, int]] = None,
    rounds: int = 3,
    stride: int = 1,
    max_client_layers: Optional[int] = None,
    seed: int = 0,
) -> CutSearchResult:
    """Coordinate descent over per-client cuts.

    ``instance_builder(cuts)`` must return an Instance whose delays reflect
    the given per-client cuts (see profiling.scenarios.instance_builder_for).
    """
    rng = np.random.default_rng(seed)
    cands = candidate_cuts(num_layers, stride=stride,
                           max_client_layers=max_client_layers)
    cut0 = init_cut if init_cut is not None else cands[len(cands) // 2]
    cuts = [cut0] * J
    evals = 0
    history = []

    def evaluate(cur_cuts):
        nonlocal evals
        inst = instance_builder(cur_cuts)
        assign = assign_balanced(inst)
        sched = full_schedule_for_assignment(inst, assign)
        evals += 1
        return inst, sched, sched.makespan(inst)

    inst, sched, best = evaluate(cuts)
    history.append({"round": 0, "makespan": best})

    for rnd in range(1, rounds + 1):
        improved = False
        # sweep clients from most to least critical
        order = sorted(range(J), key=lambda j: -sched.completion(inst, j))
        for j in order:
            best_local = None
            # sample a subset of candidates for scalability
            pool = cands if len(cands) <= 12 else \
                [cands[i] for i in rng.choice(len(cands), 12, replace=False)]
            if cuts[j] not in pool:
                pool = pool + [cuts[j]]
            for cut in pool:
                if cut == cuts[j]:
                    continue
                trial = list(cuts)
                trial[j] = cut
                try:
                    t_inst, t_sched, mk = evaluate(trial)
                except ValueError:
                    continue  # infeasible memory packing for this cut
                if mk < best:
                    best_local = (cut, t_inst, t_sched, mk)
                    best = mk
            if best_local is not None:
                cuts[j] = best_local[0]
                inst, sched = best_local[1], best_local[2]
                improved = True
        history.append({"round": rnd, "makespan": best})
        if not improved:
            break

    check_feasible(inst, sched)
    return CutSearchResult(cuts=cuts, schedule=sched, instance=inst,
                           makespan=best, evaluations=evals, history=history)
