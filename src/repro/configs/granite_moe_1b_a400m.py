"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]:
MoE with 32 experts, top-8 routing, GQA kv=8."""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,  # expert FFN width
    vocab_size=49155,
    block_pattern=("attn",),
    mlp_kind="moe",
    moe=MoEConfig(num_experts=32, experts_per_token=8, expert_d_ff=512,
                  num_shared_experts=0),
    rope_theta=10000.0,
    tie_embeddings=True,
    sl_cut=(2, 22),
)
