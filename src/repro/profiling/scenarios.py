"""Scenario generators: turn testbed profiles / transformer cost models into
``core.Instance`` problems (Sec. VII setup).

* Scenario 1 (low heterogeneity): devices drawn uniformly from Table I pools,
  identical cut layers for all clients, memory = device RAM.
* Scenario 2 (high heterogeneity): per-entity speeds interpolated between the
  profiled devices, random per-client cut layers, random memory <= RAM.
* ``transformer_instance``: the same machinery applied to any of the 10
  assigned architectures via the analytic cost model.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.instance import Instance
from . import cost_model
from .devices import DEVICES, LinkModel, Device
from .testbed_models import TESTBED_MODELS, TestbedModel

# per-model slot lengths used in the paper's experiments (Sec. VII)
PAPER_SLOT_S = {"resnet101": 0.180, "vgg19": 0.550}

# Default client pool. rpi3 (1 GB) cannot train locally (Table I) and its
# extrapolated compute time (~330 s/batch) would dominate every makespan,
# making scheduling irrelevant; the paper's reported horizons (T=294 for
# J=10 ResNet101) are only consistent with the faster client set. rpi3 can
# still be requested explicitly via ``include_rpi3=True``.
_CLIENTS_SL = ["rpi4", "jetson_cpu", "jetson_gpu"]
_CLIENTS_SL_FULL = ["rpi4", "rpi3", "jetson_cpu", "jetson_gpu"]
_HELPERS = ["vm8", "m1"]


def _bwd_frac(model: str) -> float:
    # Fig. 5: bwd/fwd asymmetry differs per model; VGG19 is more bwd-heavy.
    return {"resnet101": 1.8, "vgg19": 2.3}.get(model, 2.0)


def _cnn_part_times(tm: TestbedModel, total_s: float, cut, bwd_mult: float):
    s1, s2 = cut
    fwd_total = total_s / (1.0 + bwd_mult)
    f = tm.flop_frac
    fw = (fwd_total * f[:s1].sum(), fwd_total * f[s1:s2].sum(),
          fwd_total * f[s2:].sum())
    return fw


def _device_time(dev: Device, model: str, speed_mult: float = 1.0) -> float:
    """Measured batch time; falls back to FLOP-rate scaling (rpi3)."""
    t = (dev.table1 or {}).get(model)
    if t is None:
        ref = DEVICES["rpi4"]
        t = ref.table1[model] * ref.flops / dev.flops
    return t / speed_mult


def cnn_instance(
    model: str = "resnet101",
    J: int = 10,
    I: int = 2,
    *,
    scenario: int = 1,
    seed: int = 0,
    slot_s: Optional[float] = None,
    batch: int = 128,
    include_rpi3: bool = False,
) -> Instance:
    """Build an Instance from the paper's testbed measurements."""
    tm = TESTBED_MODELS[model]
    slot_s = slot_s if slot_s is not None else PAPER_SLOT_S[model]
    rng = np.random.default_rng(seed)
    link = LinkModel()
    bwd = _bwd_frac(model)

    pool = _CLIENTS_SL_FULL if include_rpi3 else _CLIENTS_SL
    client_devs = [DEVICES[pool[rng.integers(len(pool))]] for _ in range(J)]
    helper_devs = [DEVICES[_HELPERS[rng.integers(len(_HELPERS))]]
                   for _ in range(I)]
    if scenario == 2:
        cmult = rng.uniform(0.6, 1.8, size=J)   # interpolated speeds
        hmult = rng.uniform(0.5, 2.0, size=I)
        # random per-client cuts, but part-2 stays the LARGEST part (the SL
        # premise: clients offload the bulk of the model, Sec. I)
        L = tm.num_layers
        cuts = [(int(rng.integers(1, max(2, L // 5))),
                 int(rng.integers(L - max(2, L // 5), L)))
                for _ in range(J)]
        # "a few helpers with very limited memory capacities" (Sec. VII)
        mem = np.array([rng.uniform(0.08, 0.6) * h.memory_gb for h in helper_devs])
    else:
        cmult = np.ones(J)
        hmult = np.ones(I)
        cuts = [tm.default_cut] * J
        mem = np.array([h.memory_gb for h in helper_devs])

    shape = (I, J)
    r = np.zeros(shape, np.int64); p = np.zeros(shape, np.int64)
    l = np.zeros(shape, np.int64); lp = np.zeros(shape, np.int64)
    pp = np.zeros(shape, np.int64); rp = np.zeros(shape, np.int64)
    d = np.zeros(J)
    for j in range(J):
        s1, s2 = cuts[j]
        up, down = link.sample(rng)
        ct = _device_time(client_devs[j], model, cmult[j])
        fw = _cnn_part_times(tm, ct, (s1, s2), bwd)
        a1 = tm.act_bytes[s1]
        a2 = tm.act_bytes[s2]
        # helper memory demand: part-2 params (opt states) + activations.
        # Activations stored bf16 with recompute (x0.25 of fp32-all), which
        # calibrates to the paper's feasible loads (~10 clients / 16 GB).
        p2_params = tm.param_bytes[s1:s2].sum()
        d[j] = (p2_params * 3 + tm.act_bytes[s1:s2].sum() * 0.25) / 1e9
        for i in range(I):
            ht = _device_time(helper_devs[i], model, hmult[i])
            hf = _cnn_part_times(tm, ht, (s1, s2), bwd)

            def slots(t, minimum=0):
                return max(int(np.ceil(t / slot_s)), minimum)

            r[i, j] = slots(fw[0] + a1 / up)
            p[i, j] = slots(hf[1], 1)
            l[i, j] = slots(a2 / down + fw[2])
            lp[i, j] = slots(bwd * fw[2] + a2 / up)
            pp[i, j] = slots(bwd * hf[1], 1)
            rp[i, j] = slots(a1 / down + bwd * fw[0])
    mem = _ensure_packable(mem, d)
    inst = Instance(r=r, p=p, l=l, lp=lp, pp=pp, rp=rp, d=d, m=mem)
    inst.assert_assignable()
    return inst


def _ensure_packable(mem: np.ndarray, d: np.ndarray) -> np.ndarray:
    """Scale helper memories minimally so a feasible assignment exists
    (total slack + every task fits somewhere), keeping tightness intact."""
    mem = mem.copy()
    if mem.sum() < 1.3 * d.sum():
        mem *= 1.3 * d.sum() / mem.sum()
    big = int(np.argmax(mem))
    if mem[big] < d.max() * 1.05:
        mem[big] = d.max() * 1.05
    return mem


def transformer_instance(
    cfg: ModelConfig,
    J: int = 10,
    I: int = 2,
    *,
    batch: int = 8,
    seq: int = 512,
    scenario: int = 1,
    seed: int = 0,
    slot_s: float = 0.2,
    helper_flops_mult: float = 1.0,
) -> Instance:
    """The paper's scheduler applied to an assigned architecture: clients
    fine-tune `cfg` with SL, helpers host part-2."""
    rng = np.random.default_rng(seed)
    link = LinkModel()
    client_devs = [DEVICES[_CLIENTS_SL[rng.integers(len(_CLIENTS_SL))]]
                   for _ in range(J)]
    helper_devs = [DEVICES[_HELPERS[rng.integers(len(_HELPERS))]]
                   for _ in range(I)]
    if scenario == 2:
        cmult = rng.uniform(0.6, 1.8, size=J)
        hmult = rng.uniform(0.5, 2.0, size=I) * helper_flops_mult
        L = cfg.num_layers
        cuts = []
        for _ in range(J):
            s1 = int(rng.integers(1, max(2, L // 5)))
            lo2 = max(s1 + 1, L - max(2, L // 5))
            s2 = min(int(rng.integers(lo2, L + 1)), L)
            cuts.append((s1, s2))
        mem = np.array([rng.uniform(0.15, 0.7) * h.memory_gb * 4  # server-class
                        for h in helper_devs])
    else:
        cmult = np.ones(J)
        hmult = np.ones(I) * helper_flops_mult
        cuts = [cfg.sl_cuts_resolved] * J
        mem = np.array([h.memory_gb * 4 for h in helper_devs])

    shape = (I, J)
    r = np.zeros(shape, np.int64); p = np.zeros(shape, np.int64)
    l = np.zeros(shape, np.int64); lp = np.zeros(shape, np.int64)
    pp = np.zeros(shape, np.int64); rp = np.zeros(shape, np.int64)
    d = np.zeros(J)
    for j in range(J):
        costs = cost_model.split_costs(cfg, batch, seq, cut=cuts[j])
        d[j] = cost_model.helper_memory_demand_gb(costs)
        up, down = link.sample(rng)
        cdev = dataclasses.replace(client_devs[j],
                                   flops=client_devs[j].flops * cmult[j])
        for i in range(I):
            hdev = dataclasses.replace(helper_devs[i],
                                       flops=helper_devs[i].flops * hmult[i])
            e = cost_model.edge_delays(costs, cdev, hdev, up, down, slot_s)
            r[i, j], p[i, j], l[i, j] = e.r, e.p, e.l
            lp[i, j], pp[i, j], rp[i, j] = e.lp, e.pp, e.rp
    mem = _ensure_packable(mem, d)
    inst = Instance(r=r, p=p, l=l, lp=lp, pp=pp, rp=rp, d=d, m=mem)
    inst.assert_assignable()
    return inst


def instance_builder_for(model: str, J: int, I: int, *, seed: int = 0,
                         slot_s: Optional[float] = None):
    """Freeze the environment (devices, speeds, links, memories) and return
    a ``cuts -> Instance`` closure for core.cut_search (only the cut layers
    vary between evaluations)."""
    tm = TESTBED_MODELS[model]
    slot = slot_s if slot_s is not None else PAPER_SLOT_S[model]
    rng = np.random.default_rng(seed)
    link = LinkModel()
    bwd = _bwd_frac(model)
    client_devs = [DEVICES[_CLIENTS_SL[rng.integers(len(_CLIENTS_SL))]]
                   for _ in range(J)]
    helper_devs = [DEVICES[_HELPERS[rng.integers(len(_HELPERS))]]
                   for _ in range(I)]
    cmult = rng.uniform(0.6, 1.8, size=J)
    hmult = rng.uniform(0.5, 2.0, size=I)
    links = [link.sample(rng) for _ in range(J)]
    mem_base = np.array([rng.uniform(0.3, 1.0) * h.memory_gb
                         for h in helper_devs])

    def build(cuts):
        shape = (I, J)
        r = np.zeros(shape, np.int64); p = np.zeros(shape, np.int64)
        l = np.zeros(shape, np.int64); lp = np.zeros(shape, np.int64)
        pp = np.zeros(shape, np.int64); rp = np.zeros(shape, np.int64)
        d = np.zeros(J)
        for j in range(J):
            s1, s2 = cuts[j]
            up, down = links[j]
            ct = _device_time(client_devs[j], model, cmult[j])
            fw = _cnn_part_times(tm, ct, (s1, s2), bwd)
            a1, a2 = tm.act_bytes[s1], tm.act_bytes[s2]
            p2_params = tm.param_bytes[s1:s2].sum()
            d[j] = (p2_params * 3 + tm.act_bytes[s1:s2].sum() * 0.25) / 1e9
            for i in range(I):
                ht = _device_time(helper_devs[i], model, hmult[i])
                hf = _cnn_part_times(tm, ht, (s1, s2), bwd)

                def slots(t, minimum=0):
                    return max(int(np.ceil(t / slot)), minimum)

                r[i, j] = slots(fw[0] + a1 / up)
                p[i, j] = slots(hf[1], 1)
                l[i, j] = slots(a2 / down + fw[2])
                lp[i, j] = slots(bwd * fw[2] + a2 / up)
                pp[i, j] = slots(bwd * hf[1], 1)
                rp[i, j] = slots(a1 / down + bwd * fw[0])
        mem = _ensure_packable(mem_base, d)
        inst = Instance(r=r, p=p, l=l, lp=lp, pp=pp, rp=rp, d=d, m=mem)
        inst.assert_assignable()
        return inst

    return build
