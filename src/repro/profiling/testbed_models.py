"""Per-layer profiles of the paper's testbed models (ResNet101, VGG19 on
CIFAR-10, batch 128).

The paper treats a model as a sequence of indivisible "layers" (37 for
ResNet101, 25 for VGG19) and profiles per-layer compute on each device.
We reconstruct per-layer compute FRACTIONS and cut activation sizes from the
architectures themselves (channel/spatial dims on 32x32 inputs); per-device
absolute times are anchored to the measured Table I batch times, so e.g.
RPi4 ResNet101 per-batch time sums to 91.9 s by construction.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .devices import Device


@dataclasses.dataclass(frozen=True)
class TestbedModel:
    name: str
    num_layers: int
    flop_frac: np.ndarray      # [L] fractions summing to 1
    act_bytes: np.ndarray      # [L+1] activation bytes at each cut point
    param_bytes: np.ndarray    # [L] parameter bytes per layer
    default_cut: tuple         # paper Scenario 1 cut layers

    def batch_time(self, device: Device, model_times: dict) -> float:
        t = model_times.get(self.name)
        if t is None:  # no measurement: scale from a reference device
            return None
        return t


def _resnet101_profile(batch: int = 128) -> TestbedModel:
    # CIFAR-10 ResNet101: stem + [3, 4, 23, 3] bottleneck blocks + head = 34
    # blocks; the paper counts 37 indivisible layers (stem, 34 blocks, pool,
    # fc). Spatial 32->32->16->8->4.
    chans = [64] + [256] * 3 + [512] * 4 + [1024] * 23 + [2048] * 3 + [2048, 10]
    spatial = [32] + [32] * 3 + [16] * 4 + [8] * 23 + [4] * 3 + [1, 1]
    L = len(chans)  # 37
    flops = []
    params = []
    for i in range(L):
        c, s = chans[i], spatial[i]
        c_in = chans[i - 1] if i else 3
        if i in (L - 2, L - 1):  # pool + fc
            f = c_in * c * 2.0
            p = c_in * c
        else:
            f = 2.0 * (c_in * c // 4 + (c // 4) ** 2 * 9 + (c // 4) * c) * s * s
            p = c_in * c // 4 + (c // 4) ** 2 * 9 + (c // 4) * c
        flops.append(f * batch)
        params.append(p * 4)
    flops = np.array(flops)
    acts = np.array([batch * chans[min(i, L - 1)] * spatial[min(i, L - 1)] ** 2 * 4
                     for i in range(L + 1)], dtype=float)
    acts[0] = batch * 3 * 32 * 32 * 4
    return TestbedModel("resnet101", L, flops / flops.sum(), acts,
                        np.array(params, float), default_cut=(3, 33))


def _vgg19_profile(batch: int = 128) -> TestbedModel:
    # VGG19: 16 conv + 5 pool-ish markers + 3 fc -> paper counts 25 layers
    conv_ch = [64, 64, 128, 128, 256, 256, 256, 256,
               512, 512, 512, 512, 512, 512, 512, 512]
    pool_after = {1, 3, 7, 11, 15}
    spatial = 32
    layers = []
    c_in = 3
    for i, c in enumerate(conv_ch):
        layers.append(("conv", c_in, c, spatial))
        c_in = c
        if i in pool_after:
            layers.append(("pool", c, c, spatial))
            spatial //= 2
    layers += [("fc", 512, 512, 1), ("fc", 512, 512, 1), ("fc", 512, 10, 1)]
    L = len(layers)  # 24 (+input marker ~ paper's 25)
    flops, params, acts = [], [], []
    for kind, ci, co, s in layers:
        if kind == "conv":
            f = 2.0 * ci * co * 9 * s * s
            p = ci * co * 9
        elif kind == "pool":
            f = co * s * s * 1.0
            p = 0
        else:
            f = 2.0 * ci * co
            p = ci * co
        flops.append(f * batch)
        params.append(p * 4)
        acts.append(batch * co * s * s * 4)
    flops = np.array(flops)
    acts = np.array([batch * 3 * 32 * 32 * 4] + acts, dtype=float)
    return TestbedModel("vgg19", L, flops / flops.sum(), acts,
                        np.array(params, float), default_cut=(3, 23))


RESNET101 = _resnet101_profile()
VGG19 = _vgg19_profile()
TESTBED_MODELS = {"resnet101": RESNET101, "vgg19": VGG19}
