"""PaliGemma-3B [arXiv:2407.07726]: SigLIP vision stub + Gemma decoder (MQA).

The SigLIP-400M vision tower + projector is a STUB per the task spec:
``input_specs()`` provides 256 precomputed patch embeddings of width d_model.
The language decoder below is fully implemented.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    block_pattern=("attn",),
    mlp_kind="geglu",
    rope_theta=10000.0,
    frontend="vision",
    frontend_tokens=256,
    sl_cut=(1, 17),
)
