"""Attention: GQA with RoPE, sliding windows, softcapping, QK-norm, and
DeepSeek-style Multi-head Latent Attention (MLA). Includes decode caches.

Two compute paths:
  * ``dot``       — materializes [.., S_q, S_k] scores (short sequences);
  * ``blockwise`` — lax.scan over KV blocks with an online softmax (long
    sequences; the pure-JAX analogue of the Pallas flash kernel, and the
    oracle it is tested against).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from .norms import rmsnorm

BLOCKWISE_THRESHOLD = 2048  # switch to online-softmax attention beyond this
NEG_INF = -1e30


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, positions):
    """positions: [...]; returns cos, sin of shape [..., head_dim//2]."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, D]; rotates pairs (d, d+half)."""
    half = x.shape[-1] // 2
    cos, sin = rope_freqs(x.shape[-1], theta, positions)  # [B, S, half]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _softcap(scores, cap: Optional[float]):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


# --------------------------------------------------------------------------
# Masks
# --------------------------------------------------------------------------
INVALID_POS = 2 ** 30  # sentinel for unfilled cache slots / padding


def attn_mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """[.., S_q, S_k] boolean mask; True = attend."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = (k_pos < INVALID_POS // 2)[..., None, :]  # exclude sentinel slots
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    return ok


# --------------------------------------------------------------------------
# Core attention computations
# --------------------------------------------------------------------------
def _dot_attention(q, k, v, mask, softcap):
    """q: [B,Sq,H,D], k: [B,Sk,KV,D], v: [B,Sk,KV,Dv], H = KV*rep.
    mask: [B,Sq,Sk]. Dv may differ from D (MLA)."""
    B, Sq, H, D = q.shape
    KV, Dv = k.shape[2], v.shape[3]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, D)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(D))
    scores = _softcap(scores, softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w, v)
    return out.reshape(B, Sq, H, Dv)


def _blockwise_attention(q, k, v, q_pos, k_pos, *, causal, window, softcap,
                         block: int = 1024):
    """Online-softmax attention over KV blocks (O(S) memory)."""
    B, Sq, H, D = q.shape
    Sk, KV, Dv = k.shape[1], k.shape[2], v.shape[3]
    rep = H // KV
    nblk = -(-Sk // block)
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=INVALID_POS)
    kb = k.reshape(B, nblk, block, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, KV, Dv).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(B, nblk, block).transpose(1, 0, 2)
    qg = q.reshape(B, Sq, KV, rep, D)

    def step(carry, inp):
        m, l, acc = carry
        kc, vc, pc = inp
        s = jnp.einsum("bqkrd,bskd->bkrqs", qg, kc).astype(jnp.float32)
        s = s / jnp.sqrt(jnp.float32(D))
        s = _softcap(s, softcap)
        ok = attn_mask(q_pos, pc, causal=causal, window=window)
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkrqs,bskd->bkrqd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, rep, Sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, KV, rep, Sq), dtype=jnp.float32)
    a0 = jnp.zeros((B, KV, rep, Sq, Dv), dtype=jnp.float32)
    # checkpoint each KV-block step: backward recomputes the [.., Sq, block]
    # probability tile instead of storing all of them (flash-style memory)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def multi_head_attention(q, k, v, q_pos, k_pos, *, causal, window, softcap,
                         force_blockwise: Optional[bool] = None):
    use_blockwise = (k.shape[1] > BLOCKWISE_THRESHOLD
                     if force_blockwise is None else force_blockwise)
    if use_blockwise:
        return _blockwise_attention(q, k, v, q_pos, k_pos, causal=causal,
                                    window=window, softcap=softcap)
    mask = attn_mask(q_pos, k_pos, causal=causal, window=window)
    return _dot_attention(q, k, v, mask, softcap)


# --------------------------------------------------------------------------
# GQA block mixer
# --------------------------------------------------------------------------
def gqa_forward(params, x, positions, cfg: ModelConfig, *, window=None,
                kv_override=None, seq_parallel: Optional[tuple] = None):
    """x: [B, S, d] -> [B, S, d].

    ``kv_override``: (k, v, k_pos) for decode against a cache.
    ``seq_parallel``: (data_axes, model_axis) — shard queries along seq over
    the model axis and replicate K/V (for head counts < model-axis size).
    """
    B, S, _ = x.shape
    H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.use_qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv_override is not None:
        k, v, k_pos = kv_override(k, v)
    else:
        k_pos = positions
    if seq_parallel is not None and kv_override is None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh, data_axes, model_axis = seq_parallel
        ns = lambda spec: NamedSharding(mesh, spec)
        q = jax.lax.with_sharding_constraint(
            q, ns(P(tuple(data_axes), model_axis, None, None)))
        k = jax.lax.with_sharding_constraint(
            k, ns(P(tuple(data_axes), None, None, None)))
        v = jax.lax.with_sharding_constraint(
            v, ns(P(tuple(data_axes), None, None, None)))
    out = multi_head_attention(q, k, v, positions, k_pos,
                               causal=cfg.causal, window=window,
                               softcap=cfg.attn_softcap)
    if seq_parallel is not None and kv_override is None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh, data_axes, model_axis = seq_parallel
        out = jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, P(tuple(data_axes), None, None, None)))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# --------------------------------------------------------------------------
# MLA (DeepSeek V3)
# --------------------------------------------------------------------------
def mla_forward(params, x, positions, cfg: ModelConfig, *, cache_override=None):
    """Multi-head Latent Attention.

    Query path:  x -> wq_a [d, qr] -> norm -> wq_b [qr, H*(dn+dr)]
    KV path:     x -> wkv_a [d, kvr + dr]; latent c_kv normed; k_rope shared
                 across heads; wkv_b [kvr, H*(dn+dv)].
    ``cache_override(c_kv, k_rope)`` returns full-history (c_kv, k_rope,
    k_pos) for decode.
    """
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q_lat = jnp.einsum("bsd,dq->bsq", x, params["wq_a"])
    q_lat = rmsnorm(q_lat, params["q_norm"])
    q = jnp.einsum("bsq,qhk->bshk", q_lat, params["wq_b"])  # k = dn + dr
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dk->bsk", x, params["wkv_a"])  # k = kvr + dr
    c_kv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = rmsnorm(c_kv, params["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cache_override is not None:
        c_kv, k_rope, k_pos = cache_override(c_kv, k_rope)
    else:
        k_pos = positions

    kvb = jnp.einsum("bsk,khv->bshv", c_kv, params["wkv_b"])  # v = dn + dv
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_rope.shape[:2], H, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = multi_head_attention(q_full, k, v, positions, k_pos,
                               causal=True, window=None, softcap=None)
    return jnp.einsum("bshv,hvd->bsd", out, params["wo"])
