"""End-to-end driver: train a transformer with PARALLEL SPLIT LEARNING for a
few hundred steps, with the workflow optimized by the paper's solution
strategy and re-optimized when the environment changes.

The model is a ~10M-parameter gemma2-family config (pass --preset 100m for a
~100M config if you have the CPU budget — same code path).

Run:  PYTHONPATH=src python examples/sl_train_e2e.py --rounds 25
"""

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core import solve_strategy
from repro.data.synthetic import SyntheticLM
from repro.profiling.scenarios import transformer_instance
from repro.sl.runtime import ParallelSLTrainer


def build_cfg(preset: str):
    base = get_config("gemma2-2b")
    if preset == "100m":
        return base.reduced(num_layers=8, d_model=512, vocab=32000)
    return base.reduced(num_layers=4, d_model=256, vocab=4096)  # ~10M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["10m", "100m"], default="10m")
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--steps-per-round", type=int, default=4)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--helpers", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    inst = transformer_instance(cfg, J=args.clients, I=args.helpers,
                                scenario=2, seed=0, slot_s=0.05,
                                batch=args.batch, seq=args.seq)
    strat = solve_strategy(inst, refine=True, refine_budget_s=5.0)
    print(f"[e2e] workflow optimized with `{strat.method}`: "
          f"batch makespan {strat.makespan} slots (T={inst.T})")

    trainer = ParallelSLTrainer(cfg, inst, strat.schedule, lr=3e-3)
    gen = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    client_batches = [next(gen.batches(1)) for _ in range(args.clients)]
    eval_batch = next(gen.batches(1))

    t0 = time.perf_counter()
    total_steps = 0
    for r in range(args.rounds):
        st = trainer.run_round(client_batches, local_steps=args.steps_per_round)
        total_steps += args.steps_per_round * args.clients
        if r % 5 == 0 or r == args.rounds - 1:
            ev = trainer.eval_loss(eval_batch)
            print(f"[e2e] round {st.round_idx:3d}: train {st.mean_loss:.4f} "
                  f"eval {ev:.4f} | simulated "
                  f"{st.simulated_time_slots * 0.05:.1f}s/round "
                  f"| wall {time.perf_counter() - t0:.0f}s")
    rep = trainer.report()
    print(f"[e2e] done: {total_steps} SL batch updates across "
          f"{args.clients} clients")
    print(rep.summary())


if __name__ == "__main__":
    main()
