"""Scheduling deep-dive: compare all four methods on one instance, verify
feasibility, inspect queuing delays, preemption costs, and the Gantt chart.

Run:  PYTHONPATH=src python examples/schedule_and_simulate.py
"""

import numpy as np

from repro.core import (check_feasible, lower_bound, queuing_delay,
                        solve_admm, solve_balanced_greedy, solve_baseline,
                        solve_exact, solve_local_search)
from repro.profiling.scenarios import cnn_instance
from repro.sl.simulator import gantt, simulate

inst = cnn_instance("vgg19", J=10, I=3, scenario=2, seed=3)
print(f"J={inst.J} I={inst.I} T={inst.T} lower bound={lower_bound(inst)}\n")

methods = {
    "baseline (random+FCFS)": solve_baseline(inst, seed=0),
    "balanced-greedy": solve_balanced_greedy(inst),
    "ADMM + Alg.2": solve_admm(inst, mode="fast", tau_max=8),
    "local search (beyond-paper)": solve_local_search(inst, time_budget_s=10),
}
for name, res in methods.items():
    check_feasible(inst, res.schedule)
    rep = simulate(inst, res.schedule)
    q = [queuing_delay(inst, res.schedule, j) for j in range(inst.J)]
    util = np.mean(list(rep.helper_util.values()))
    print(f"{name:30s} makespan={res.makespan:4d}  "
          f"mean queue={np.mean(q):5.1f}  mean helper util={util:.0%}")

best = min(methods.items(), key=lambda kv: kv[1].makespan)
print(f"\nbest: {best[0]} — Gantt:")
print(gantt(inst, best[1].schedule, width=80))

# preemption-cost extension (Sec. VI): charge 1 slot per task switch
import numpy as _np
object.__setattr__(inst, "mu", _np.ones(inst.I))
for name, res in methods.items():
    mk = res.schedule.makespan_with_preemption_cost(inst)
    print(f"{name:30s} makespan with switching costs: {mk:.0f}")

# exact optimum on a small slice of the same scenario
small = cnn_instance("vgg19", J=4, I=2, scenario=2, seed=3,
                     slot_s=0.550 * 4)
ex = solve_exact(small, time_limit=60)
print(f"\nexact optimum on a scaled-down instance (J=4): "
      f"{ex.schedule.makespan(small)} ({ex.status})")
