"""Launcher / dry-run tests. The real 512-device sweep runs via
``repro.launch.dryrun``; here we verify the machinery on an 8-device host
mesh in a subprocess (device count must be set before jax initializes)."""

import json
import subprocess
import sys

import pytest

from repro.launch.roofline import collective_bytes, _group_size


def test_collective_bytes_parser():
    hlo = """
  %all-reduce.1 = f32[8,128]{1,0} all-reduce(%fusion.1), replica_groups=[16,16]<=[256], use_global_device_ids=true
  %all-gather.2 = bf16[64,32]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %all-to-all.3 = (f32[4,8]{1,0}, f32[4,8]{1,0}) all-to-all(%a, %b), replica_groups=[8,2]<=[16]
  %reduce-scatter.4 = f32[16]{0} reduce-scatter(%x), replica_groups=[1,4]<=[4]
  %add.5 = f32[999,999]{1,0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    # all-reduce: 2*(15/16)*8*128*4
    assert out["all-reduce"] == int(2 * 15 / 16 * 8 * 128 * 4)
    # all-gather: (3/4)*64*32*2
    assert out["all-gather"] == int(3 / 4 * 64 * 32 * 2)
    # all-to-all tuple: (1/2)*(2*4*8*4)
    assert out["all-to-all"] == int(0.5 * 2 * 4 * 8 * 4)
    # reduce-scatter: (g-1)*result = 3*16*4
    assert out["reduce-scatter"] == 3 * 16 * 4
    assert out["collective-permute"] == 0


def test_group_size_formats():
    assert _group_size("replica_groups=[16,32]<=[512]") == 32
    assert _group_size("replica_groups={{0,1,2},{3,4,5}}") == 3
    assert _group_size("no groups here", default=7) == 7


_SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.configs import get_config, INPUT_SHAPES
from repro.configs.base import InputShape
from repro.launch.steps import lower_step
from repro.launch.roofline import analyze, memory_summary

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("ARCH").reduced(num_layers=2, d_model=256, vocab=512)
shape = InputShape("t", 64, 8, "KIND")
lowered, meta = lower_step(cfg, mesh, shape)
compiled = lowered.compile()
roof = analyze(compiled)
mem = memory_summary(compiled)
print(json.dumps({"flops": roof.flops, "bytes": roof.bytes_accessed,
                  "coll": roof.coll_bytes, "kind": meta["kind"],
                  "temp": mem.get("temp_size_in_bytes", 0)}))
"""


def _run_sub(arch: str, kind: str) -> dict:
    prog = _SUBPROCESS_PROG.replace("ARCH", arch).replace("KIND", kind)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert res.returncode == 0, res.stderr[-2000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,kind", [
    ("gemma2-2b", "train"),
    ("granite-moe-1b-a400m", "train"),   # MoE ep_a2a + shard_map grads
    ("zamba2-2.7b", "train"),            # hybrid + shared attention
    ("gemma3-27b", "decode"),            # windowed + full caches
])
def test_lower_compile_small_mesh(arch, kind):
    out = _run_sub(arch, kind)
    assert out["flops"] > 0
    assert out["bytes"] > 0
    assert out["kind"] == kind
    if kind == "train":
        # grad sync must appear as collective traffic
        assert sum(out["coll"].values()) > 0
