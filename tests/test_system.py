"""End-to-end behaviour tests for the full system: workflow optimization ->
real parallel-SL execution -> aggregation, exactly as a deployment would
run it (the examples' code path)."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import check_feasible, solve_strategy
from repro.data.synthetic import SyntheticLM
from repro.profiling.scenarios import transformer_instance
from repro.sl.runtime import ParallelSLTrainer
from repro.sl.simulator import simulate


@pytest.fixture(scope="module")
def e2e():
    cfg = get_config("gemma2-2b").reduced(num_layers=2, d_model=64, vocab=128)
    inst = transformer_instance(cfg, J=4, I=2, scenario=2, seed=1,
                                slot_s=0.05, batch=2, seq=32)
    strat = solve_strategy(inst, refine=True, refine_budget_s=2.0)
    check_feasible(inst, strat.schedule)
    trainer = ParallelSLTrainer(cfg, inst, strat.schedule, lr=5e-3)
    gen = SyntheticLM(cfg.vocab_size, 32, 2, seed=0)
    batches = [next(gen.batches(1)) for _ in range(inst.J)]
    stats = [trainer.run_round(batches, local_steps=2) for _ in range(5)]
    return cfg, inst, strat, trainer, stats


def test_optimized_workflow_trains_the_model(e2e):
    _, _, _, _, stats = e2e
    losses = [s.mean_loss for s in stats]
    assert losses[-1] < losses[0] - 0.3, losses


def test_makespan_is_reported_and_consistent(e2e):
    _, inst, strat, trainer, stats = e2e
    assert stats[0].batch_makespan_slots == strat.makespan
    rep = trainer.report()
    assert rep.makespan == strat.makespan
    assert simulate(inst, strat.schedule).makespan == strat.makespan


def test_traffic_matches_cost_model(e2e):
    """Bytes actually crossing the cuts equal the analytic cost model's
    prediction (per batch per client: 2 legs x 2 cuts)."""
    cfg, inst, _, _, stats = e2e
    B, S, d = 2, 32, cfg.d_model
    per_leg = B * S * d * 4  # f32 activations in the CPU runtime
    expected_per_step = inst.J * 2 * (per_leg + per_leg)
    assert stats[0].cut_traffic_bytes == expected_per_step * 2  # 2 local steps


def test_strategy_never_worse_than_baseline(e2e):
    from repro.core import solve_baseline
    _, inst, strat, _, _ = e2e
    base = min(solve_baseline(inst, seed=s).makespan for s in range(3))
    assert strat.makespan <= base
