"""Model configuration system.

Every assigned architecture is described by a ``ModelConfig``. The layer
stack is a repeating ``block_pattern`` of block kinds:

  "attn"        full-attention transformer block
  "local"       sliding-window attention block
  "mla"         multi-head latent attention block (DeepSeek)
  "mamba"       Mamba2 / SSD block
  "shared_attn" attention block with weights SHARED across occurrences
                (Zamba2-style)

plus per-block MLP kind ("swiglu" | "geglu" | "gelu" | "relu2" | "moe").
``block_pattern`` is tiled to ``num_layers``; a leading ``first_k_dense``
overrides the MLP of the first k blocks to be dense (DeepSeek-V3).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    expert_d_ff: int
    num_shared_experts: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_size: int = 128
    conv_kernel: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    chunk_size: int = 64  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.ssm_head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    block_pattern: Tuple[str, ...] = ("attn",)
    mlp_kind: str = "swiglu"  # default MLP for every block
    first_k_dense: int = 0  # DeepSeek: first k blocks use dense MLP w/ d_ff
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    sliding_window: int = 4096
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    use_qk_norm: bool = False
    use_post_norm: bool = False  # gemma2/3 style post-block norms
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    causal: bool = True  # False for encoder-only (hubert)
    tie_embeddings: bool = True
    mtp_depth: int = 0  # DeepSeek multi-token prediction heads
    # modality frontends are STUBS: input_specs() provides embeddings directly
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_tokens: int = 0  # prefix length contributed by the stub frontend
    dtype: str = "bfloat16"
    # --- split-learning defaults (cut layers sigma1, sigma2; Sec. I) -------
    sl_cut: Tuple[int, int] = (1, -1)  # -1 => L-1 (last layer on client)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.num_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.num_layers]

    def mlp_kind_for_layer(self, idx: int) -> str:
        if idx < self.first_k_dense:
            return "swiglu" if self.mlp_kind == "moe" else self.mlp_kind
        return self.mlp_kind

    @property
    def sl_cuts_resolved(self) -> Tuple[int, int]:
        s1, s2 = self.sl_cut
        if s2 < 0:
            s2 = self.num_layers + s2
        return s1, s2

    def param_count(self) -> int:
        """Analytic parameter count (used by cost model & docs)."""
        from repro.profiling.cost_model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.profiling.cost_model import count_params
        return count_params(self, active_only=True)

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant: same family/pattern, tiny dims (spec: <=2
        layers, d_model<=512, <=4 experts)."""
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(heads, self.num_kv_heads if self.num_kv_heads else heads))
        if heads % kv:
            kv = 1
        kw = dict(
            arch_id=self.arch_id + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=max(8, d_model // heads),
            d_ff=d_model * 4,
            vocab_size=vocab,
            sliding_window=64,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend else 0,
            # part-1 = first layer, part-2 = the rest, part-3 = head (part-2
            # must be non-empty — it is the offloaded task)
            sl_cut=(1, num_layers) if num_layers > 1 else (0, num_layers),
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=4, experts_per_token=2,
                expert_d_ff=d_model * 2,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                capacity_factor=2.0,
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(state_size=16, conv_kernel=4, expand=2,
                                  ssm_head_dim=32, chunk_size=16)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
