"""Beyond-paper: assignment local search with optimal inner scheduling.

The paper's two methods either iterate ADMM (quality, slow) or balance loads
greedily (fast, assignment-only). We add a third method: local search over
assignments (move / swap neighborhoods) where EVERY candidate assignment is
evaluated with the *optimal* preemptive fwd schedule (Baker) followed by the
*optimal* bwd schedule (Algorithm 2). Since the inner problem given y is
polynomial (per-helper decomposition + Theorem 2 machinery), the search
explores the assignment space with exact makespan evaluations — something
neither paper method does. Recorded separately in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .balanced_greedy import assign_balanced
from .bwd_schedule import full_schedule_for_assignment
from .instance import Instance
from .schedule import Schedule, check_feasible


@dataclasses.dataclass
class LocalSearchResult:
    schedule: Schedule
    makespan: int
    runtime_s: float
    evaluations: int
    moves_taken: int


def _mem_ok(inst: Instance, assign: np.ndarray) -> bool:
    for i in range(inst.I):
        if sum(inst.d[j] for j in range(inst.J) if assign[j] == i) > inst.m[i] + 1e-9:
            return False
    return True


def solve_local_search(
    inst: Instance,
    *,
    init: Optional[np.ndarray] = None,
    max_rounds: int = 20,
    time_budget_s: float = 30.0,
    horizon: Optional[int] = None,
    seed: int = 0,
) -> LocalSearchResult:
    """First-improvement local search over move/swap neighborhoods.

    Focuses the neighborhood on the makespan-critical client (the argmax of
    c_j), which is where a move can actually reduce the objective.
    """
    t0 = time.perf_counter()
    T = int(horizon if horizon is not None else inst.T)
    rng = np.random.default_rng(seed)
    assign = (init.copy() if init is not None else assign_balanced(inst))
    sched = full_schedule_for_assignment(inst, assign, horizon=T)
    best_mk = sched.makespan(inst)
    evals, moves = 1, 0

    for _ in range(max_rounds):
        if time.perf_counter() - t0 > time_budget_s:
            break
        completions = [sched.completion(inst, j) for j in range(inst.J)]
        # try moving each of the k most critical clients
        critical = list(np.argsort(completions)[::-1][: min(5, inst.J)])
        improved = False
        for j in critical:
            j = int(j)
            cur = int(assign[j])
            cands = [i for i in inst.feasible_helpers(j) if i != cur]
            rng.shuffle(cands)
            for i in cands:
                trial = assign.copy()
                trial[j] = i
                if not _mem_ok(inst, trial):
                    continue
                cand = full_schedule_for_assignment(inst, trial, horizon=T)
                evals += 1
                mk = cand.makespan(inst)
                if mk < best_mk:
                    assign, sched, best_mk = trial, cand, mk
                    improved, moves = True, moves + 1
                    break
            if improved or time.perf_counter() - t0 > time_budget_s:
                break
        if not improved:
            # swap neighborhood: critical client with a client on another helper
            jc = int(np.argmax(completions))
            others = [j for j in range(inst.J) if assign[j] != assign[jc]]
            rng.shuffle(others)
            for j2 in others[: 2 * inst.J]:
                trial = assign.copy()
                trial[jc], trial[j2] = assign[j2], assign[jc]
                if not (inst.is_edge(int(trial[jc]), jc)
                        and inst.is_edge(int(trial[j2]), j2)
                        and _mem_ok(inst, trial)):
                    continue
                cand = full_schedule_for_assignment(inst, trial, horizon=T)
                evals += 1
                mk = cand.makespan(inst)
                if mk < best_mk:
                    assign, sched, best_mk = trial, cand, mk
                    improved, moves = True, moves + 1
                    break
                if time.perf_counter() - t0 > time_budget_s:
                    break
        if not improved:
            break

    check_feasible(inst, sched, horizon=T)
    return LocalSearchResult(schedule=sched, makespan=best_mk,
                             runtime_s=time.perf_counter() - t0,
                             evaluations=evals, moves_taken=moves)
