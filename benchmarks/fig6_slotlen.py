"""Fig. 6 reproduction: batch makespan vs time-slot length |S_t|
(Observation 2: coarser slots -> shorter horizon/faster solve, but less
precise schedule -> longer makespan in real time units)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import solve_admm
from repro.profiling.scenarios import cnn_instance

SLOT_MS = [50, 150, 200]


def run(model: str = "resnet101", J: int = 15, I: int = 3, seeds=(0, 1, 2)):
    rows = []
    for slot in SLOT_MS:
        mks, horizons, times = [], [], []
        for seed in seeds:
            inst = cnn_instance(model, J=J, I=I, scenario=1, seed=seed,
                                slot_s=slot / 1000.0)
            t0 = time.perf_counter()
            res = solve_admm(inst, mode="fast", tau_max=8)
            times.append(time.perf_counter() - t0)
            mks.append(res.makespan * slot / 1000.0)  # back to seconds
            horizons.append(inst.T)
        rows.append({
            "model": model, "slot_ms": slot,
            "makespan_s": round(float(np.mean(mks)), 2),
            "horizon_T": int(np.mean(horizons)),
            "solve_s": round(float(np.mean(times)), 3),
        })
    base = rows[0]
    for r in rows:
        r["speedup_vs_50ms"] = round(base["solve_s"] / max(r["solve_s"], 1e-9), 2)
        r["makespan_increase_pct"] = round(
            100.0 * (r["makespan_s"] - base["makespan_s"]) / base["makespan_s"], 1)
    return rows


def main():
    rows = run()
    print("slot_ms  makespan_s  horizon_T  solve_s  speedup  mk_increase%")
    for r in rows:
        print(f"{r['slot_ms']:7d} {r['makespan_s']:11.2f} {r['horizon_T']:10d} "
              f"{r['solve_s']:8.3f} {r['speedup_vs_50ms']:8.2f} "
              f"{r['makespan_increase_pct']:12.1f}")
    return rows


if __name__ == "__main__":
    main()
