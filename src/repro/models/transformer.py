"""Generic transformer/MoE/SSM/hybrid model built from a ModelConfig.

* ``model_plan`` declares every parameter (shape, logical axes, init kind);
  init / abstract-shape / PartitionSpec trees are all derived from the one
  plan, so sharding rules can never drift from the actual parameters.
* Layers with the same (kind, mlp) signature are stacked along a leading
  "layers" axis. The forward pass either unrolls (smoke/SL) or scans over
  pattern repetitions (production; keeps HLO size O(pattern) not O(L)).
* ``shared_attn`` blocks (Zamba2) hold ONE weight copy applied at every
  occurrence.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from . import ssm as ssm_mod
from .attention import gqa_forward, mla_forward, apply_rope, multi_head_attention
from .mlp import is_gated, mlp_forward
from .moe import moe_forward
from .norms import apply_norm


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution options (how to run, not what the model is)."""
    scan_layers: bool = False
    moe_mode: str = "dense"          # dense | ep_a2a | ep_local
    mesh: Any = None                 # jax Mesh (required for ep modes)
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    long_context: bool = False       # window-cache ALL attention (zamba2 500k)
    force_blockwise: Optional[bool] = None
    remat: bool = False              # activation checkpoint each block
    # FSDP: params live sharded over data_axes (embed dim); gather each
    # block's weights JUST BEFORE use via with_sharding_constraint so the
    # SPMD partitioner all-gathers small weights instead of all-reducing
    # full-batch activations.
    fsdp_gather: bool = False
    # Sequence-parallel attention: when a model's head count cannot shard
    # over the model axis (e.g. gemma2's 8 q-heads on a 16-way axis),
    # split QUERIES along the sequence over the model axis and gather K/V —
    # attention FLOPs then divide by the model-axis size.
    seq_parallel_attn: bool = False


# ==========================================================================
# Parameter plan
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | a_log | dt_bias


def _norm_spec(cfg: ModelConfig) -> Dict[str, Spec]:
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": Spec((d,), ("embed",), "zeros")}
    return {"scale": Spec((d,), ("embed",), "ones"),
            "bias": Spec((d,), ("embed",), "zeros")}


def _attn_spec(cfg: ModelConfig) -> Dict[str, Spec]:
    d, H, KV, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": Spec((d, H, D), ("embed", "heads", None)),
        "wk": Spec((d, KV, D), ("embed", "kv_heads", None)),
        "wv": Spec((d, KV, D), ("embed", "kv_heads", None)),
        "wo": Spec((H, D, d), ("heads", None, "embed")),
    }
    if cfg.use_qk_norm:
        s["q_norm"] = Spec((D,), (None,), "zeros")
        s["k_norm"] = Spec((D,), (None,), "zeros")
    return s


def _mla_spec(cfg: ModelConfig) -> Dict[str, Spec]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    return {
        "wq_a": Spec((d, m.q_lora_rank), ("embed", None)),
        "q_norm": Spec((m.q_lora_rank,), (None,), "zeros"),
        "wq_b": Spec((m.q_lora_rank, H, m.qk_nope_head_dim + m.qk_rope_head_dim),
                     (None, "heads", None)),
        "wkv_a": Spec((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None)),
        "kv_norm": Spec((m.kv_lora_rank,), (None,), "zeros"),
        "wkv_b": Spec((m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
                      (None, "heads", None)),
        "wo": Spec((H, m.v_head_dim, d), ("heads", None, "embed")),
    }


def _mlp_spec(cfg: ModelConfig, kind: str) -> Dict[str, Spec]:
    d, f = cfg.d_model, cfg.d_ff
    if kind == "moe":
        mo = cfg.moe
        s = {
            "router": Spec((d, mo.num_experts), ("embed", None)),
            # expert weights get their OWN logical axes so FSDP sharding of
            # experts can be toggled independently (perf iteration knob)
            "wi": Spec((mo.num_experts, d, 2, mo.expert_d_ff),
                       ("expert", "moe_embed", None, "moe_mlp")),
            "wo": Spec((mo.num_experts, mo.expert_d_ff, d),
                       ("expert", "moe_mlp", "moe_embed")),
        }
        if mo.num_shared_experts:
            sf = mo.expert_d_ff * mo.num_shared_experts
            s["shared"] = {"wi": Spec((d, 2, sf), ("embed", None, "mlp")),
                           "wo": Spec((sf, d), ("mlp", "embed"))}
        return s
    if is_gated(kind):
        return {"wi": Spec((d, 2, f), ("embed", None, "mlp")),
                "wo": Spec((f, d), ("mlp", "embed"))}
    return {"wi": Spec((d, f), ("embed", "mlp")),
            "wo": Spec((f, d), ("mlp", "embed"))}


def _mamba_spec(cfg: ModelConfig) -> Dict[str, Spec]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    H = s.num_ssm_heads(d)
    conv_dim = d_in + 2 * s.state_size
    proj_out = 2 * d_in + 2 * s.state_size + H
    return {
        "in_proj": Spec((d, proj_out), ("embed", "mlp")),
        "conv_w": Spec((s.conv_kernel, conv_dim), (None, "mlp")),
        "conv_b": Spec((conv_dim,), ("mlp",), "zeros"),
        "dt_bias": Spec((H,), (None,), "dt_bias"),
        "A_log": Spec((H,), (None,), "a_log"),
        "D": Spec((H,), (None,), "ones"),
        "norm": Spec((d_in,), ("mlp",), "zeros"),
        "out_proj": Spec((d_in, d), ("mlp", "embed")),
    }


def block_plan(cfg: ModelConfig, kind: str, mlp_kind: str) -> Dict[str, Any]:
    if kind == "mamba":
        return {"ln1": _norm_spec(cfg), "mamba": _mamba_spec(cfg)}
    mixer = _mla_spec(cfg) if kind == "mla" else _attn_spec(cfg)
    plan = {"ln1": _norm_spec(cfg), "attn": mixer,
            "ln2": _norm_spec(cfg), "mlp": _mlp_spec(cfg, mlp_kind)}
    if cfg.use_post_norm:
        plan["ln1_post"] = _norm_spec(cfg)
        plan["ln2_post"] = _norm_spec(cfg)
    return plan


def layer_table(cfg: ModelConfig) -> List[Tuple[str, str, str, int]]:
    """Per layer: (kind, mlp_kind, group_key, index_within_group)."""
    counters: Dict[str, int] = {}
    table = []
    for idx, kind in enumerate(cfg.layer_kinds):
        mlp_kind = "-" if kind == "mamba" else cfg.mlp_kind_for_layer(idx)
        key = "shared" if kind == "shared_attn" else f"{kind}:{mlp_kind}"
        pos = 0 if key == "shared" else counters.get(key, 0)
        if key != "shared":
            counters[key] = pos + 1
        table.append((kind, mlp_kind, key, pos))
    return table


def group_counts(cfg: ModelConfig) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for kind, mlp_kind, key, pos in layer_table(cfg):
        if key == "shared":
            counts[key] = 1
        else:
            counts[key] = max(counts.get(key, 0), pos + 1)
    return counts


def _stack(plan: Dict[str, Any], n: int) -> Dict[str, Any]:
    def f(leaf: Spec) -> Spec:
        return Spec((n,) + leaf.shape, ("layers",) + leaf.axes, leaf.init)
    return jax.tree.map(f, plan, is_leaf=lambda x: isinstance(x, Spec))


def model_plan(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    plan: Dict[str, Any] = {
        "embed": Spec((cfg.vocab_size, d), ("vocab", "embed")),
        "final_norm": _norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        plan["lm_head"] = Spec((d, cfg.vocab_size), ("embed", "vocab"))
    groups: Dict[str, Any] = {}
    table = layer_table(cfg)
    for key, n in group_counts(cfg).items():
        kind, mlp_kind = next((k, m) for k, m, kk, _ in table if kk == key)
        bp = block_plan(cfg, kind, mlp_kind)
        groups[key] = bp if key == "shared" else _stack(bp, n)
    plan["groups"] = groups
    if cfg.mtp_depth > 0:
        kind, mlp_kind = table[-1][0], table[-1][1]
        plan["mtp"] = {
            "proj": Spec((2 * d, d), (None, "embed")),
            "norm_h": _norm_spec(cfg),
            "norm_e": _norm_spec(cfg),
            "block": block_plan(cfg, kind, mlp_kind),
        }
    return plan


# ---- plan materialization -------------------------------------------------
def _init_leaf(spec: Spec, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "a_log":
        base = jnp.log(jnp.linspace(1.0, 16.0, spec.shape[-1], dtype=jnp.float32))
        return jnp.broadcast_to(base, spec.shape).astype(jnp.float32)
    if spec.init == "dt_bias":
        return jnp.zeros(spec.shape, jnp.float32)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = 0.02 if spec.init == "normal" else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    plan = model_plan(cfg)
    leaves, treedef = jax.tree.flatten(plan, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    def f(s: Spec):
        dt = jnp.float32 if s.init in ("a_log", "dt_bias") else dtype
        return jax.ShapeDtypeStruct(s.shape, dt)
    return jax.tree.map(f, model_plan(cfg), is_leaf=lambda x: isinstance(x, Spec))


def param_pspecs(cfg: ModelConfig, rules: Dict[str, Any]):
    """rules: logical axis name -> mesh axis (str | tuple | None)."""
    from jax.sharding import PartitionSpec as P

    def f(s: Spec):
        return P(*[rules.get(a) if a else None for a in s.axes])
    return jax.tree.map(f, model_plan(cfg), is_leaf=lambda x: isinstance(x, Spec))


# "compute" sharding of weights: tensor-parallel dims stay sharded, the FSDP
# (embed) dim is gathered at use
GATHER_RULES = {"vocab": "model", "embed": None, "heads": "model",
                "kv_heads": "model", "mlp": "model", "expert": "model",
                "moe_embed": None, "moe_mlp": None, "layers": None}


@functools.lru_cache(maxsize=64)
def gather_shardings(cfg: ModelConfig, mesh) -> Dict[str, Any]:
    """Per-group NamedSharding trees for SLICED (per-layer) block params,
    plus entries for 'embed'/'lm_head'/'final_norm'/'mtp'."""
    from jax.sharding import NamedSharding
    from repro.launch.mesh import auto_pspec

    def leaf(s: Spec, drop_layers: bool):
        axes = s.axes[1:] if (drop_layers and s.axes and s.axes[0] == "layers") \
            else s.axes
        shape = s.shape[1:] if (drop_layers and s.axes
                                and s.axes[0] == "layers") else s.shape
        wanted = [GATHER_RULES.get(a) if a else None for a in axes]
        return NamedSharding(mesh, auto_pspec(shape, wanted, mesh))

    plan = model_plan(cfg)
    out: Dict[str, Any] = {}
    for key, sub in plan["groups"].items():
        out[key] = jax.tree.map(lambda s: leaf(s, key != "shared"), sub,
                                is_leaf=lambda x: isinstance(x, Spec))
    for key in ("embed", "lm_head", "final_norm", "mtp"):
        if key in plan:
            out[key] = jax.tree.map(lambda s: leaf(s, False), plan[key],
                                    is_leaf=lambda x: isinstance(x, Spec))
    return out


def _maybe_gather(cfg: ModelConfig, rt: Runtime, key: str, tree):
    if not rt.fsdp_gather or rt.mesh is None:
        return tree
    return jax.lax.with_sharding_constraint(tree,
                                            gather_shardings(cfg, rt.mesh)[key])


# ==========================================================================
# Forward
# ==========================================================================
def _window_for(cfg: ModelConfig, kind: str, rt: Runtime) -> Optional[int]:
    if kind == "local":
        return cfg.sliding_window
    if rt.long_context and kind in ("attn", "shared_attn"):
        return cfg.sliding_window  # documented long_500k adaptation
    return None


def block_forward(cfg: ModelConfig, kind: str, mlp_kind: str, bp, x, positions,
                  rt: Runtime, cache=None, decode_pos=None):
    """One block. Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(x, bp["ln1"], cfg.norm)
    new_cache = cache
    if kind == "mamba":
        if cache is not None:
            out, new_cache = ssm_mod.mamba2_forward(bp["mamba"], h, cfg, state=cache)
        else:
            out = ssm_mod.mamba2_forward(bp["mamba"], h, cfg)
        if cfg.use_post_norm:
            out = apply_norm(out, bp.get("ln1_post", bp["ln1"]), cfg.norm)
        return x + out, aux, new_cache

    window = _window_for(cfg, kind, rt)
    if kind == "mla":
        if cache is not None:
            def override(ckv, kr):
                c2 = jax.lax.dynamic_update_slice(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, decode_pos, 0))
                k2 = jax.lax.dynamic_update_slice(
                    cache["kr"], kr.astype(cache["kr"].dtype), (0, decode_pos, 0))
                new_c = {"ckv": c2, "kr": k2}
                k_pos = jnp.broadcast_to(jnp.arange(c2.shape[1])[None],
                                         (c2.shape[0], c2.shape[1]))
                return c2, k2, k_pos, new_c
            box = {}
            def ov(ckv, kr):
                c2, k2, kp, nc = override(ckv, kr)
                box["cache"] = nc
                return c2, k2, kp
            out = mla_forward(bp["attn"], h, positions, cfg, cache_override=ov)
            new_cache = box["cache"]
        else:
            out = mla_forward(bp["attn"], h, positions, cfg)
    else:
        if cache is not None:
            W = cache["k"].shape[1]
            slot = decode_pos % W if W < 10 ** 9 else decode_pos
            box = {}
            def kv_override(k, v):
                k2 = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
                v2 = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
                kp = jax.lax.dynamic_update_slice(
                    cache["pos"], jnp.broadcast_to(
                        decode_pos, (k.shape[0], 1)).astype(cache["pos"].dtype),
                    (0, slot))
                box["cache"] = {"k": k2, "v": v2, "pos": kp}
                return k2, v2, kp
            out = gqa_forward(bp["attn"], h, positions, cfg, window=window,
                              kv_override=kv_override)
            new_cache = box["cache"]
        else:
            sp = ((rt.mesh, rt.data_axes, rt.model_axis)
                  if rt.seq_parallel_attn and rt.mesh is not None else None)
            out = gqa_forward(bp["attn"], h, positions, cfg, window=window,
                              seq_parallel=sp)
    if cfg.use_post_norm:
        out = apply_norm(out, bp["ln1_post"], cfg.norm)
    x = x + out

    h = apply_norm(x, bp["ln2"], cfg.norm)
    if mlp_kind == "moe":
        out, aux = moe_forward(bp["mlp"], h, cfg, mode=rt.moe_mode,
                               mesh=rt.mesh, data_axes=rt.data_axes,
                               model_axis=rt.model_axis)
    else:
        out = mlp_forward(bp["mlp"], h, mlp_kind)
    if cfg.use_post_norm:
        out = apply_norm(out, bp["ln2_post"], cfg.norm)
    return x + out, aux, new_cache


def _embed(cfg: ModelConfig, params, tokens, rt: Optional[Runtime] = None):
    emb = params["embed"]
    if rt is not None:
        emb = _maybe_gather(cfg, rt, "embed", emb)
    e = emb[tokens]
    return e * jnp.asarray(np.sqrt(cfg.d_model), e.dtype)


def _unembed(cfg: ModelConfig, params, h, rt: Optional[Runtime] = None):
    if cfg.tie_embeddings:
        emb = params["embed"]
        if rt is not None:
            emb = _maybe_gather(cfg, rt, "embed", emb)
        logits = jnp.einsum("bsd,vd->bsv", h, emb)
    else:
        head = params["lm_head"]
        if rt is not None:
            head = _maybe_gather(cfg, rt, "lm_head", head)
        logits = jnp.einsum("bsd,dv->bsv", h, head)
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def forward_hidden(cfg: ModelConfig, params, x, positions, rt: Runtime,
                   caches=None, decode_pos=None):
    """Run all blocks. x: [B, S, d] embeddings. Returns (h, aux, new_caches)."""
    table = layer_table(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = None if caches is None else list(caches)

    blk = functools.partial(block_forward, cfg)
    if rt.remat:
        # kind, mlp_kind, rt are static; bp/x/positions/cache are arrays
        blk = jax.checkpoint(blk, static_argnums=(0, 1, 5))

    if not rt.scan_layers or caches is not None:
        # unrolled path (smoke, SL, decode)
        for li, (kind, mlp_kind, key, pos) in enumerate(table):
            bp = params["groups"][key]
            if key != "shared":
                bp = jax.tree.map(lambda a: a[pos], bp)
            bp = _maybe_gather(cfg, rt, key, bp)
            cache = None if caches is None else caches[li]
            x, aux, nc = blk(kind, mlp_kind, bp, x, positions, rt,
                             cache, decode_pos)
            aux_total = aux_total + aux
            if caches is not None:
                new_caches[li] = nc
        return x, aux_total, new_caches

    # scanned path: repetitions of the block pattern
    P = len(cfg.block_pattern)
    R = cfg.num_layers // P
    occ = {}  # per-group occurrences per repetition
    for kind in cfg.block_pattern:
        mlp_kind = "-" if kind == "mamba" else cfg.mlp_kind  # pattern-level
        key = "shared" if kind == "shared_attn" else f"{kind}:{mlp_kind}"
        occ[key] = occ.get(key, 0) + 1

    # deepseek first_k_dense layers are a DIFFERENT group -> run them
    # unrolled first, then scan the homogeneous tail.
    lead = cfg.first_k_dense
    for li in range(lead):
        kind, mlp_kind, key, pos = table[li]
        bp = jax.tree.map(lambda a: a[pos], params["groups"][key])
        bp = _maybe_gather(cfg, rt, key, bp)
        x, aux, _ = blk(kind, mlp_kind, bp, x, positions, rt, None, None)
        aux_total = aux_total + aux
    # recompute repetition count for the scanned tail
    tail_layers = cfg.num_layers - lead
    R = tail_layers // P
    rem = tail_layers - R * P

    scan_tree = {}
    for key, o in occ.items():
        if key == "shared":
            continue
        stack = params["groups"][key]
        # occurrences of this group inside the scanned region
        def take(a, o=o):
            lead_in_group = sum(1 for t in table[:lead] if t[2] == key)
            sl = a[lead_in_group: lead_in_group + R * o]
            return sl.reshape((R, o) + sl.shape[1:])
        scan_tree[key] = jax.tree.map(take, stack)

    pattern = []
    cnt: Dict[str, int] = {}
    for kind in cfg.block_pattern:
        mlp_kind = "-" if kind == "mamba" else cfg.mlp_kind
        key = "shared" if kind == "shared_attn" else f"{kind}:{mlp_kind}"
        pattern.append((kind, mlp_kind, key, cnt.get(key, 0)))
        cnt[key] = cnt.get(key, 0) + 1

    shared_bp = params["groups"].get("shared")
    if shared_bp is not None:
        shared_bp = _maybe_gather(cfg, rt, "shared", shared_bp)

    def body(carry, sl):
        xx, aux_acc = carry
        for kind, mlp_kind, key, o in pattern:
            if key == "shared":
                bp = shared_bp  # gathered once outside the scan
            else:
                bp = jax.tree.map(lambda a, o=o: a[o], sl[key])
                bp = _maybe_gather(cfg, rt, key, bp)
            xx, aux, _ = blk(kind, mlp_kind, bp, xx, positions, rt, None, None)
            aux_acc = aux_acc + aux
        return (xx, aux_acc), None

    if R > 0:
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), scan_tree)

    # remainder layers (pattern does not divide num_layers)
    for li in range(cfg.num_layers - rem, cfg.num_layers):
        kind, mlp_kind, key, pos = table[li]
        bp = (shared_bp if key == "shared"
              else jax.tree.map(lambda a: a[pos], params["groups"][key]))
        bp = _maybe_gather(cfg, rt, key, bp)
        x, aux, _ = blk(kind, mlp_kind, bp, x, positions, rt, None, None)
        aux_total = aux_total + aux
    return x, aux_total, None


def _embed_batch(cfg: ModelConfig, params, batch, rt: Optional[Runtime] = None):
    if cfg.frontend == "audio":
        return batch["frames"]
    x = _embed(cfg, params, batch["tokens"], rt)
    if cfg.frontend == "vision":
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
            rt: Runtime, *, return_hidden: bool = False):
    """Full forward -> (logits [B,S,V], aux). Handles modality stubs."""
    x = _embed_batch(cfg, params, batch, rt)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, aux, _ = forward_hidden(cfg, params, x, positions, rt)
    hn = apply_norm(h, params["final_norm"], cfg.norm)
    logits = _unembed(cfg, params, hn, rt)
    if return_hidden:
        return logits, aux, h, x, positions
    return logits, aux


def cross_entropy(logits, labels, mask=None):
    """CE via a one-hot contraction rather than take_along_axis: under SPMD
    the gather over a vocab-sharded axis forces a batch-unsharded reshard,
    while the one-hot product reduces locally per vocab shard."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    label_logit = jnp.sum(logits * onehot, axis=-1)
    ll = label_logit - lse
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(cfg: ModelConfig, params, batch, rt: Runtime):
    """Training loss. LM: next-token CE (+MoE aux, +MTP). Encoder: frame CE."""
    logits, aux, h, x, positions = forward(cfg, params, batch, rt,
                                           return_hidden=True)
    if cfg.frontend == "audio":
        loss = cross_entropy(logits, batch["labels"])
    else:
        S_text = batch["tokens"].shape[1]
        text_logits = logits[:, -S_text:]
        loss = cross_entropy(text_logits[:, :-1], batch["tokens"][:, 1:])
    total = loss + (cfg.moe.router_aux_coef * aux if cfg.moe else 0.0)

    if cfg.mtp_depth > 0 and "tokens" in batch:
        total = total + 0.3 * _mtp_loss(cfg, params, batch, rt, h, x, positions)
    return total, {"ce": loss, "aux": aux}


def _mtp_loss(cfg: ModelConfig, params, batch, rt: Runtime, h, x, positions):
    """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from the
    backbone hidden at t combined with the embedding of token t+1. Reuses
    the main forward's hidden states (one extra block, not a second pass)."""
    tokens = batch["tokens"]
    mp = params["mtp"]
    # keep the FULL sequence length (sharding divisibility); shift by rolling
    # and mask the wrapped tail out of the loss
    x_next = jnp.roll(x, -1, axis=1)
    h_n = apply_norm(h, mp["norm_h"], cfg.norm)
    e_n = apply_norm(x_next, mp["norm_e"], cfg.norm)
    hin = jnp.einsum("bsk,kd->bsd", jnp.concatenate([h_n, e_n], -1), mp["proj"])
    kind, mlp_kind = layer_table(cfg)[-1][0], layer_table(cfg)[-1][1]
    hout, _, _ = block_forward(cfg, kind, mlp_kind, mp["block"], hin,
                               positions, rt)
    logits = _unembed(cfg, params, apply_norm(hout, params["final_norm"],
                                              cfg.norm), rt)
    S = tokens.shape[1]
    labels = jnp.roll(tokens, -2, axis=1)
    mask = (jnp.arange(S) < S - 2)[None, :].astype(jnp.float32)
    mask = jnp.broadcast_to(mask, tokens.shape)
    return cross_entropy(logits, labels, mask)


# ==========================================================================
# Decode caches + serve step
# ==========================================================================
def init_caches(cfg: ModelConfig, batch: int, max_len: int, rt: Runtime,
                dtype=jnp.bfloat16, abstract: bool = False):
    """Per-layer cache list (python list indexed by layer)."""
    KV, D = cfg.num_kv_heads, cfg.resolved_head_dim

    def make(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    caches = []
    for kind, mlp_kind, key, pos in layer_table(cfg):
        if kind == "mamba":
            s = cfg.ssm
            d_in = s.d_inner(cfg.d_model)
            conv_dim = d_in + 2 * s.state_size
            caches.append((make((batch, s.conv_kernel - 1, conv_dim), dtype),
                           make((batch, s.num_ssm_heads(cfg.d_model),
                                 s.ssm_head_dim, s.state_size), jnp.float32)))
        elif kind == "mla":
            m = cfg.mla
            caches.append({
                "ckv": make((batch, max_len, m.kv_lora_rank), dtype),
                "kr": make((batch, max_len, m.qk_rope_head_dim), dtype)})
        else:
            windowed = (kind == "local") or (rt.long_context
                                             and kind in ("attn", "shared_attn"))
            W = min(cfg.sliding_window, max_len) if windowed else max_len
            # "pos" starts at FUTURE (2**30) so unfilled slots are excluded by
            # the causal mask (q_pos - 2**30 < 0)
            caches.append({
                "k": make((batch, W, KV, D), dtype),
                "v": make((batch, W, KV, D), dtype),
                "pos": make((batch, W), jnp.int32) if abstract
                else jnp.full((batch, W), 2 ** 30, jnp.int32)})
    return caches


def serve_step(cfg: ModelConfig, params, caches, tokens, pos, rt: Runtime):
    """Decode ONE token. tokens: [B, 1]; pos: scalar int32 (current index).
    Returns (logits [B, 1, V], new_caches)."""
    B = tokens.shape[0]
    x = _embed(cfg, params, tokens, rt)
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    h, _, new_caches = forward_hidden(cfg, params, x, positions, rt,
                                      caches=caches, decode_pos=pos)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    return _unembed(cfg, params, h, rt), new_caches
