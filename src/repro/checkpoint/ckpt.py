"""Checkpointing: flat-key npz with step metadata. No external deps."""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    flat = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            flat.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            flat.update(_flatten(v, f"{prefix}{i}/"))
        flat[f"{prefix}__seq__"] = np.array(
            [len(tree), 1 if isinstance(tree, tuple) else 0])
    else:
        flat[prefix.rstrip("/")] = np.asarray(tree)
    return flat


def save(path: str, params, *, step: int = 0, extra: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(params))
    np.savez(path, __meta__=json.dumps({"step": step, **(extra or {})}), **flat)


def load(path: str) -> Tuple[Any, dict]:
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat = {k: z[k] for k in z.files if k != "__meta__"}

    def build(prefix: str):
        seq_key = f"{prefix}__seq__"
        if seq_key in flat:
            n, is_tuple = flat[seq_key]
            items = [build(f"{prefix}{i}/") for i in range(int(n))]
            return tuple(items) if is_tuple else items
        children = {}
        for k in flat:
            if k.startswith(prefix):
                rest = k[len(prefix):]
                head = rest.split("/")[0]
                if head and head != "__seq__":
                    children[head] = None
        if not children:
            return flat[prefix.rstrip("/")]
        return {c: build(f"{prefix}{c}/")
                if any(k.startswith(f"{prefix}{c}/") for k in flat)
                else flat[f"{prefix}{c}"] for c in children}

    return build(""), meta
