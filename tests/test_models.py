"""Model substrate consistency tests: scan vs unroll, decode vs prefill,
blockwise attention, MoE expert-parallel equivalence, split execution."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import multi_head_attention
from repro.models.split import make_split_spec, sl_batch_grads, split_params
from repro.models.transformer import (Runtime, forward, init_caches,
                                      init_params, loss_fn, serve_step)

SCAN_ARCHS = ["gemma2-2b", "zamba2-2.7b", "deepseek-v3-671b", "gemma3-27b",
              "mamba2-130m"]


@pytest.mark.parametrize("arch", SCAN_ARCHS)
def test_scan_equals_unrolled(arch):
    cfg0 = get_config(arch)
    L = max(2 * len(cfg0.block_pattern), 4)
    cfg = dataclasses.replace(cfg0.reduced(num_layers=L),
                              first_k_dense=min(cfg0.first_k_dense, 1))
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                          cfg.vocab_size)}
    lu, au = forward(cfg, params, batch, Runtime(scan_layers=False))
    ls, as_ = forward(cfg, params, batch, Runtime(scan_layers=True))
    np.testing.assert_allclose(lu, ls, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(au, as_, atol=1e-4)


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-130m", "zamba2-2.7b",
                                  "deepseek-v3-671b", "granite-moe-1b-a400m",
                                  "phi3-medium-14b"])
def test_decode_matches_prefill(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    rt = Runtime()
    full, _ = forward(cfg, params, {"tokens": toks}, rt)
    caches = init_caches(cfg, B, 32, rt, dtype=jnp.float32)
    outs = []
    step = jax.jit(lambda c, t, p: serve_step(cfg, params, c, t, p, rt))
    for t in range(S):
        lg, caches = step(caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=5e-3, rtol=1e-3)


def test_window_cache_ring_buffer():
    """Decode beyond the window: ring-buffer cache must equal prefill logits
    for a pure sliding-window model."""
    cfg = dataclasses.replace(get_config("gemma3-27b").reduced(),
                              block_pattern=("local",), sliding_window=8)
    params = init_params(cfg, jax.random.PRNGKey(4))
    B, S = 1, 24  # 3x the window
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    rt = Runtime()
    full, _ = forward(cfg, params, {"tokens": toks}, rt)
    caches = init_caches(cfg, B, S, rt, dtype=jnp.float32)
    step = jax.jit(lambda c, t, p: serve_step(cfg, params, c, t, p, rt))
    outs = []
    for t in range(S):
        lg, caches = step(caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), full, atol=5e-3, rtol=1e-3)


def test_blockwise_attention_matches_dot():
    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 2, 96, 8, 4, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    for causal in (True, False):
        for window in (None, 24):
            a = multi_head_attention(q, k, v, pos, pos, causal=causal,
                                     window=window, softcap=None,
                                     force_blockwise=False)
            b = multi_head_attention(q, k, v, pos, pos, causal=causal,
                                     window=window, softcap=None,
                                     force_blockwise=True)
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_split_grads_match_full_model():
    """Chained-vjp split gradients == full-model gradients (same loss)."""
    cfg = get_config("phi3-medium-14b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(6))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                                          cfg.vocab_size)}
    rt = Runtime()
    spec, p1, p2, p3 = split_params(cfg, params)
    loss_sl, g1, g2, g3, _ = sl_batch_grads(cfg, spec, p1, p2, p3, batch, rt)

    def full_loss(p):
        loss, _ = loss_fn(cfg, p, batch, rt)
        return loss

    loss_full, g_full = jax.value_and_grad(full_loss)(params)
    np.testing.assert_allclose(loss_sl, loss_full, atol=1e-5, rtol=1e-5)
    # embed grad: in SL, embed gets contributions from p1 (embedding) AND p3
    # (tied head) separately; the full grad is their sum
    ge = g1["embed"] + g3.get("embed_out", 0)
    np.testing.assert_allclose(ge, g_full["embed"] if cfg.tie_embeddings
                               else g1["embed"], atol=1e-4, rtol=1e-3)
    # a middle layer's grads must match exactly
    s1, _ = spec.cut
    table_key = None
    from repro.models.transformer import layer_table
    kind, mlp_kind, key, pos = layer_table(cfg)[s1]
    got = g2["layers"][0]
    want = jax.tree.map(lambda a: a[pos], g_full["groups"][key])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-4,
                                                         rtol=1e-3),
                 got, want)


def test_vlm_prefix_handling():
    cfg = get_config("paligemma-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(8))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0,
                                     cfg.vocab_size),
        "patches": jax.random.normal(jax.random.PRNGKey(10),
                                     (2, cfg.frontend_tokens, cfg.d_model)),
    }
    logits, _ = forward(cfg, params, batch, Runtime())
    assert logits.shape == (2, 16 + cfg.frontend_tokens, cfg.vocab_size)
    loss, _ = loss_fn(cfg, params, batch, Runtime())
    assert bool(jnp.isfinite(loss))
    # loss must depend on the patches
    batch2 = dict(batch, patches=batch["patches"] + 1.0)
    loss2, _ = loss_fn(cfg, params, batch2, Runtime())
    assert abs(float(loss) - float(loss2)) > 1e-6
