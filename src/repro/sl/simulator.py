"""Slot-level simulator: executes a Schedule as a discrete-event timeline.

Replays the batch-processing workflow of Fig. 2 (release -> fwd-prop slots ->
l -> l' -> bwd-prop slots -> r') and cross-checks the analytic completion
times of ``core.schedule``. Also reports helper utilization and queuing
delays — the quantities the paper's workflow optimization targets.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.instance import Instance
from repro.core.schedule import Schedule, queuing_delay


@dataclasses.dataclass
class ClientTimeline:
    client: int
    helper: int
    release: int          # r: activations arrive at helper
    fwd_slots: List[int]
    bwd_ready: int        # phi^f + l + l': gradients arrive at helper
    bwd_slots: List[int]
    completion: int       # c_j
    queuing: int


@dataclasses.dataclass
class SimReport:
    makespan: int
    timelines: List[ClientTimeline]
    helper_busy: Dict[int, int]
    helper_util: Dict[int, float]

    def summary(self) -> str:
        lines = [f"makespan={self.makespan}"]
        for i, u in sorted(self.helper_util.items()):
            lines.append(f"  helper {i}: busy={self.helper_busy[i]} slots, "
                         f"util={u:.1%}")
        return "\n".join(lines)


def simulate(inst: Instance, sched: Schedule) -> SimReport:
    timelines = []
    busy: Dict[int, int] = {i: 0 for i in range(inst.I)}
    for j in range(inst.J):
        i = int(sched.assign[j])
        xs = [int(t) for t in sched.x_slots[j]]
        zs = [int(t) for t in sched.z_slots[j]]
        release = int(inst.r[i, j])
        assert not xs or xs[0] >= release
        phi_f = (xs[-1] + 1) if xs else 0
        bwd_ready = phi_f + int(inst.l[i, j]) + int(inst.lp[i, j])
        assert not zs or zs[0] >= bwd_ready
        completion = ((zs[-1] + 1) if zs else bwd_ready) + int(inst.rp[i, j])
        assert completion == sched.completion(inst, j)
        busy[i] += len(xs) + len(zs)
        timelines.append(ClientTimeline(
            client=j, helper=i, release=release, fwd_slots=xs,
            bwd_ready=bwd_ready, bwd_slots=zs, completion=completion,
            queuing=queuing_delay(inst, sched, j)))
    mk = max(t.completion for t in timelines)
    util = {i: busy[i] / mk if mk else 0.0 for i in busy}
    return SimReport(makespan=mk, timelines=timelines,
                     helper_busy=busy, helper_util=util)


def gantt(inst: Instance, sched: Schedule, *, width: int = 100) -> str:
    """ASCII Gantt chart of helper occupancy (f=fwd, b=bwd, .=idle)."""
    mk = sched.makespan(inst)
    scale = max(1, -(-mk // width))
    rows = []
    for i in range(inst.I):
        row = []
        occ = {}
        for j in range(inst.J):
            if int(sched.assign[j]) != i:
                continue
            for t in sched.x_slots[j]:
                occ[int(t)] = "f"
            for t in sched.z_slots[j]:
                occ[int(t)] = "b"
        for t0 in range(0, mk, scale):
            cell = [occ.get(t) for t in range(t0, min(t0 + scale, mk))]
            syms = [c for c in cell if c]
            row.append(syms[0] if syms else ".")
        rows.append(f"H{i:<2d} |" + "".join(row) + "|")
    return "\n".join(rows)
