"""Hypothesis property tests on scheduling invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (Instance, check_feasible, full_schedule_for_assignment,
                        lower_bound, solve_balanced_greedy, solve_admm)
from repro.core.balanced_greedy import assign_balanced


@st.composite
def instances(draw):
    J = draw(st.integers(2, 8))
    I = draw(st.integers(1, 3))
    def arr(lo, hi):
        return np.array(
            draw(st.lists(st.lists(st.integers(lo, hi), min_size=J, max_size=J),
                          min_size=I, max_size=I)), dtype=np.int64)
    inst = Instance(
        r=arr(0, 6), p=arr(1, 8), l=arr(0, 5), lp=arr(0, 5),
        pp=arr(1, 9), rp=arr(0, 6),
        d=np.ones(J), m=np.full(I, float(J)),  # ample memory
    )
    return inst


@given(instances())
@settings(max_examples=25, deadline=None)
def test_greedy_always_feasible(inst):
    res = solve_balanced_greedy(inst)
    check_feasible(inst, res.schedule)
    assert lower_bound(inst) <= res.makespan <= inst.T


@given(instances())
@settings(max_examples=15, deadline=None)
def test_admm_always_feasible_and_never_worse_than_horizon(inst):
    res = solve_admm(inst, mode="fast", tau_max=4)
    check_feasible(inst, res.schedule)
    assert res.makespan <= inst.T


@given(instances())
@settings(max_examples=15, deadline=None)
def test_alg2_bwd_dominates_fcfs_bwd_given_same_fwd(inst):
    """Theorem 2: given assignment + fwd schedule, Algorithm 2's bwd schedule
    is optimal — so it is <= the FCFS bwd schedule on the same fwd prefix.

    NOTE: the end-to-end decomposition (optimal-fwd THEN optimal-bwd) is NOT
    globally optimal — hypothesis found a counterexample where greedy-fwd-
    first loses to plain FCFS overall, which matches the paper's framing
    (the decomposition is a heuristic; only P_b given P_f is exact).
    """
    from repro.core import schedule_bwd
    from repro.core.balanced_greedy import schedule_fcfs
    assign = assign_balanced(inst)
    fcfs = schedule_fcfs(inst, assign)
    check_feasible(inst, fcfs)
    # re-schedule ONLY the bwd stage with Algorithm 2, keeping fcfs's fwd
    opt_bwd = schedule_bwd(inst, fcfs)
    check_feasible(inst, opt_bwd)
    assert opt_bwd.makespan(inst) <= fcfs.makespan(inst)


@given(instances(), st.integers(0, 3))
@settings(max_examples=15, deadline=None)
def test_scaling_preserves_feasibility(inst, k):
    factor = float(2 ** k)
    scaled = inst.scaled(factor)
    res = solve_balanced_greedy(scaled)
    check_feasible(scaled, res.schedule)
    # makespan in original units is within a slot-quantization factor
    assert res.makespan * factor <= inst.T * factor
