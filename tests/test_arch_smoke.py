"""Per-architecture smoke tests (task spec): a REDUCED variant of each
assigned architecture (2 layers, d_model<=512, <=4 experts) runs one forward
and one train step on CPU; output shapes + no NaNs are asserted."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, shape_supported, INPUT_SHAPES
from repro.data.synthetic import make_batch
from repro.models.transformer import (Runtime, forward, init_caches,
                                      init_params, loss_fn, serve_step)
from repro.optim.adam import Adam

ALL_ARCHS = sorted(ARCHS)


def _smoke_setup(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 2, 32).items()}
    return cfg, params, batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params, batch = _smoke_setup(arch)
    logits, aux = forward(cfg, params, batch, Runtime())
    S = 32 + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    cfg, params, batch = _smoke_setup(arch)
    rt = Runtime()
    opt = Adam(lr=1e-3)
    state = opt.init(params)

    def step(p, s, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: loss_fn(cfg, pp, b, rt), has_aux=True)(p)
        p2, s2 = opt.update(grads, s, p)
        return p2, s2, loss

    p1, s1, loss1 = jax.jit(step)(params, state, batch)
    p2, s2, loss2 = jax.jit(step)(p1, s1, batch)
    assert bool(jnp.isfinite(loss1)) and bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss1) + 1.0  # not diverging
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b_: a + float(jnp.sum(jnp.abs(b_))),
        jax.tree.map(lambda a, b_: a.astype(jnp.float32) - b_.astype(jnp.float32),
                     p1, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if get_config(a).causal])
def test_decode_step(arch):
    cfg, params, _ = _smoke_setup(arch)
    rt = Runtime()
    caches = init_caches(cfg, 2, 16, rt, dtype=jnp.float32)
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, caches = serve_step(cfg, params, caches, toks, jnp.int32(0), rt)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_every_arch_has_a_config_module():
    import importlib
    for arch in ALL_ARCHS:
        mod = arch.replace("-", "_").replace(".", "_")
        m = importlib.import_module(f"repro.configs.{mod}")
        assert m.CONFIG.arch_id == arch
        assert m.CONFIG.source


def test_shape_support_matrix():
    """The documented skip matrix from DESIGN.md §Arch-applicability."""
    expect_long = {"gemma2-2b", "gemma3-27b", "mamba2-130m", "zamba2-2.7b"}
    got_long = {a for a in ALL_ARCHS
                if shape_supported(get_config(a), "long_500k")}
    assert got_long == expect_long
    assert not shape_supported(get_config("hubert-xlarge"), "decode_32k")
    for a in ALL_ARCHS:
        assert shape_supported(get_config(a), "train_4k")
        assert shape_supported(get_config(a), "prefill_32k")
    n_pairs = sum(shape_supported(get_config(a), s)
                  for a in ALL_ARCHS for s in INPUT_SHAPES)
    assert n_pairs == 33
