"""Aggregate the dry-run JSON results (experiments/dryrun/*.json) into the
EXPERIMENTS.md roofline table.

Memory term bounds: the graph analyzer's bytes are an UPPER bound (fusion
granularity, loop bodies multiplied); XLA's cost_analysis bytes are a LOWER
bound (while bodies counted once). Both are reported.
"""

from __future__ import annotations

import glob
import json
import os

V5E_HBM_GB = 16.0
HBM_BW = 819e9

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(dirpath: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        if path.endswith(".FAILED.json"):
            rows.append({"tag": os.path.basename(path), "failed": True})
            continue
        with open(path) as f:
            r = json.load(f)
        roof = r["roofline"]
        mem = r["memory"]
        args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        temp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
        xla_bytes = roof.get("xla_bytes") or 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "kind": r["kind"], "compile_s": r["compile_s"],
            "args_gb": round(args_gb, 2), "temp_gb": round(temp_gb, 2),
            "fits_16gb_args": args_gb <= V5E_HBM_GB,
            "compute_ms": roof["compute_s"] * 1e3,
            "memory_ms_hi": roof["memory_s"] * 1e3,
            "memory_ms_lo": xla_bytes / HBM_BW * 1e3,
            "collective_ms": roof["collective_s"] * 1e3,
            "dominant": roof["dominant"],
            "useful_ratio": roof.get("useful_ratio"),
            "flops": roof["flops"],
            "failed": False,
        })
    rows.sort(key=lambda r: (r.get("arch", ""),
                             SHAPE_ORDER.get(r.get("shape", ""), 9),
                             r.get("mesh", "")))
    return rows


def main(markdown_out: str | None = None):
    rows = load()
    ok = [r for r in rows if not r.get("failed")]
    hdr = (f"{'arch':25s} {'shape':12s} {'mesh':8s} {'comp_ms':>9s} "
           f"{'mem_lo':>8s} {'mem_hi':>9s} {'coll_ms':>8s} {'dom':>6s} "
           f"{'useful':>7s} {'args GB':>8s} {'temp GB':>8s}")
    print(hdr)
    lines_md = ["| arch | shape | mesh | compute ms | mem ms (lo-hi) | "
                "coll ms | dominant | useful | args GB | temp GB |",
                "|---|---|---|---|---|---|---|---|---|---|"]
    for r in ok:
        u = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        print(f"{r['arch']:25s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['compute_ms']:9.1f} {r['memory_ms_lo']:8.1f} "
              f"{r['memory_ms_hi']:9.1f} {r['collective_ms']:8.1f} "
              f"{r['dominant'][:6]:>6s} {u:>7s} {r['args_gb']:8.1f} "
              f"{r['temp_gb']:8.1f}")
        lines_md.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_ms']:.1f} | {r['memory_ms_lo']:.1f}-"
            f"{r['memory_ms_hi']:.0f} | {r['collective_ms']:.1f} | "
            f"{r['dominant']} | {u} | {r['args_gb']:.1f} | "
            f"{r['temp_gb']:.1f} |")
    failed = [r for r in rows if r.get("failed")]
    for r in failed:
        print("FAILED:", r["tag"])
    if markdown_out:
        with open(markdown_out, "w") as f:
            f.write("\n".join(lines_md) + "\n")
    return rows


if __name__ == "__main__":
    import sys
    main(markdown_out=sys.argv[1] if len(sys.argv) > 1 else None)
