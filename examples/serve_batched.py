"""Batched serving example: greedy decoding with KV/SSM caches across three
architecture families (dense sliding-window, SSM, MoE).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import (Runtime, init_caches, init_params,
                                      serve_step)

for arch in ("gemma2-2b", "mamba2-130m", "granite-moe-1b-a400m"):
    cfg = get_config(arch).reduced()
    rt = Runtime()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, prompt_len, gen_len = 4, 16, 24
    caches = init_caches(cfg, B, prompt_len + gen_len, rt, dtype=jnp.float32)
    prompt = jax.random.randint(key, (B, prompt_len), 0, cfg.vocab_size)
    step = jax.jit(lambda c, t, p: serve_step(cfg, params, c, t, p, rt))

    logits = None
    for t in range(prompt_len):
        logits, caches = step(caches, prompt[:, t:t + 1], jnp.int32(t))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    outs = []
    for i in range(gen_len):
        outs.append(np.asarray(tok))
        logits, caches = step(caches, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    dt = time.perf_counter() - t0
    gen = np.concatenate(outs, 1)
    print(f"{arch:24s} ({cfg.family:6s}): {B}x{gen_len} tokens in {dt:5.2f}s "
          f"({B * gen_len / dt:6.1f} tok/s)  first row: {gen[0][:10].tolist()}")
