"""Testbed device catalog (paper Table I) + link model.

Effective sustained compute rates (FLOP/s) are calibrated so that the
paper's measured batch-update times for ResNet101/VGG19 (Table I) are
reproduced by the CNN profiles in ``testbed_models.py`` (see
benchmarks/fig5_profiles.py for the calibration check). Helpers in the
paper are the VM and the M1; clients are the edge devices.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    flops: float          # effective FLOP/s (sustained, measured-equivalent)
    memory_gb: float
    is_helper: bool = False
    # measured batch-update times from Table I (seconds), when available
    table1: Optional[Dict[str, float]] = None


DEVICES = {
    "rpi4": Device("RPi 4 B (4GB)", 9.0e9, 4.0,
                   table1={"resnet101": 91.9, "vgg19": 71.9}),
    "rpi3": Device("RPi 3 B+ (1GB)", 2.5e9, 1.0,
                   table1={}),  # not enough memory to train locally
    "jetson_cpu": Device("Jetson Nano CPU", 6.0e9, 4.0,
                         table1={"resnet101": 143.0, "vgg19": 396.0}),
    "jetson_gpu": Device("Jetson Nano GPU", 4.0e11, 4.0,
                         table1={"resnet101": 1.2, "vgg19": 2.6}),
    "vm8": Device("VM 8-core vCPU (16GB)", 4.2e11, 16.0, is_helper=True,
                  table1={"resnet101": 2.0, "vgg19": 3.6}),
    "m1": Device("Apple M1 (16GB)", 2.4e11, 16.0, is_helper=True,
                 table1={"resnet101": 3.5, "vgg19": 3.6}),
}

CLIENT_POOL = ["rpi4", "rpi3", "jetson_cpu", "jetson_gpu"]
HELPER_POOL = ["vm8", "m1"]


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Average per-byte delays.

    Calibration note: the paper cites Akamai Q4'16 France (~10 Mbps avg), but
    its reported horizons (T=294 slots at 180 ms for ResNet101, J=10) imply
    per-batch activation transfers of only a few slots, i.e. effective edge
    links of ~100+ Mbps for 128x CIFAR activations. We therefore default to
    100-400 Mbps (WiFi/5G edge), which reproduces the paper's time scale;
    the slower profile is available as ``LinkModel.akamai_2016()``.
    """
    up_mbps_range: tuple = (100.0, 250.0)
    down_mbps_range: tuple = (150.0, 400.0)

    @staticmethod
    def akamai_2016() -> "LinkModel":
        return LinkModel(up_mbps_range=(5.0, 20.0),
                         down_mbps_range=(15.0, 50.0))

    def sample(self, rng: np.random.Generator):
        up = rng.uniform(*self.up_mbps_range)
        down = rng.uniform(*self.down_mbps_range)
        return up * 1e6 / 8, down * 1e6 / 8  # bytes/s


def sample_clients(J: int, rng: np.random.Generator):
    return [DEVICES[CLIENT_POOL[rng.integers(len(CLIENT_POOL))]] for _ in range(J)]


def sample_helpers(I: int, rng: np.random.Generator):
    return [DEVICES[HELPER_POOL[rng.integers(len(HELPER_POOL))]] for _ in range(I)]
