"""Config registry: ``--arch <id>`` resolution + the paper's own SL models."""

from .base import INPUT_SHAPES, InputShape, MLAConfig, ModelConfig, MoEConfig, SSMConfig
from .nemotron_4_340b import CONFIG as _nemotron
from .paligemma_3b import CONFIG as _paligemma
from .deepseek_v3_671b import CONFIG as _deepseek
from .phi3_medium_14b import CONFIG as _phi3
from .gemma2_2b import CONFIG as _gemma2
from .zamba2_2_7b import CONFIG as _zamba2
from .mamba2_130m import CONFIG as _mamba2
from .hubert_xlarge import CONFIG as _hubert
from .gemma3_27b import CONFIG as _gemma3
from .granite_moe_1b_a400m import CONFIG as _granite

ARCHS = {
    c.arch_id: c
    for c in [_nemotron, _paligemma, _deepseek, _phi3, _gemma2,
              _zamba2, _mamba2, _hubert, _gemma3, _granite]
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id.endswith("-smoke"):
        return ARCHS[arch_id[: -len("-smoke")]].reduced()
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def shape_supported(cfg: ModelConfig, shape_name: str) -> bool:
    """Which (arch x input shape) pairs run — skips documented in DESIGN.md."""
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "decode":
        if not cfg.causal:  # encoder-only (hubert): no autoregressive decode
            return False
        if shape.seq_len > 100_000:
            # long_500k needs sub-quadratic attention: SSM/hybrid families or
            # dense archs with a sliding-window variant
            kinds = set(cfg.layer_kinds)
            has_subquadratic = ("mamba" in kinds) or ("local" in kinds)
            return has_subquadratic
    return True


__all__ = ["ARCHS", "get_config", "shape_supported", "INPUT_SHAPES",
           "InputShape", "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig"]
