"""Beyond-paper: multi-batch pipelined scheduling.

The paper optimizes ONE batch update and multiplies (Sec. III, "Epochs &
Aggregation"), noting only that clients can be "moved earlier" when slots
free up. But consecutive batches of the SAME client are independent until
the round boundary, so helper idle slots within one batch's horizon can
host the NEXT batch's fwd-prop tasks. This module schedules K consecutive
batches jointly:

* every client contributes K (fwd, bwd) task chains; chain k's fwd release
  is ``r_ij + k * client_cycle`` (the client can only produce activations
  after finishing its part-1 of the previous batch),
* helper occupancy is shared across all chains,
* scheduling per helper is first-come-first-served over READY tasks with
  preemption allowed at slot boundaries (list scheduling), which preserves
  feasibility under the same constraints as the paper's model.

The metric is the K-batch makespan; the win over K * (single-batch
makespan) is the pipelining gain reported in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Tuple

import numpy as np

from .instance import Instance
from .schedule import Schedule, check_feasible


@dataclasses.dataclass
class PipelineResult:
    makespan: int                 # completion of ALL K batches
    single_batch_makespan: int    # the schedule's first-batch makespan
    sequential_makespan: int      # K x single-batch (the paper's regime)
    gain_pct: float
    per_batch_completion: List[int]


def _client_cycle(inst: Instance, i: int, j: int) -> int:
    """Min slots between consecutive fwd releases of client j (its own
    part-1 fwd + part-1 bwd of the previous batch)."""
    return max(1, int(inst.r[i, j] + inst.rp[i, j]))


def schedule_pipelined(inst: Instance, assign: np.ndarray, K: int,
                       *, horizon_mult: int = None) -> PipelineResult:
    """List-schedule K batches per client through the shared helpers."""
    T = inst.T * (K if horizon_mult is None else horizon_mult)
    J = inst.J
    # task state per (client, batch): phase 0 = fwd, 1 = bwd
    remaining = {}
    ready_at = {}
    completion = np.zeros((J, K), dtype=np.int64)
    for j in range(J):
        i = int(assign[j])
        for k in range(K):
            remaining[(j, k, 0)] = int(inst.p[i, j])
            remaining[(j, k, 1)] = int(inst.pp[i, j])
            ready_at[(j, k, 0)] = int(inst.r[i, j]) + k * _client_cycle(inst, i, j)
            ready_at[(j, k, 1)] = None  # set once fwd completes

    finished_fwd_at = {}
    for t in range(T):
        all_done = True
        for i in range(inst.I):
            # pick the ready task with earliest ready time (FCFS, preemptive)
            best = None
            for (j, k, ph), rem in remaining.items():
                if rem <= 0 or int(assign[j]) != i:
                    continue
                all_done = False
                ra = ready_at[(j, k, ph)]
                if ra is None or ra > t:
                    continue
                key = (ra, k, ph, j)
                if best is None or key < best[0]:
                    best = (key, (j, k, ph))
            if best is None:
                continue
            j, k, ph = best[1]
            remaining[(j, k, ph)] -= 1
            if remaining[(j, k, ph)] == 0:
                if ph == 0:
                    finished_fwd_at[(j, k)] = t + 1
                    ready_at[(j, k, 1)] = (t + 1 + int(inst.l[i, j])
                                           + int(inst.lp[i, j]))
                else:
                    completion[j, k] = t + 1 + int(inst.rp[i, j])
        if all_done:
            break
    if any(v > 0 for v in remaining.values()):
        raise RuntimeError("pipeline horizon too small")

    per_batch = [int(completion[:, k].max()) for k in range(K)]
    single = per_batch[0]
    seq = single * K
    mk = per_batch[-1]
    gain = 100.0 * (seq - mk) / seq
    return PipelineResult(makespan=mk, single_batch_makespan=single,
                          sequential_makespan=seq, gain_pct=gain,
                          per_batch_completion=per_batch)
