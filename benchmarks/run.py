"""Benchmark harness entry point: one benchmark per paper table/figure.

``python -m benchmarks.run [--fast]`` prints a ``name,us_per_call,derived``
CSV line per benchmark plus each benchmark's own table.
"""

from __future__ import annotations

import argparse
import time


def _timed(name, fn, derived_fn):
    t0 = time.perf_counter()
    rows = fn()
    dt = (time.perf_counter() - t0) * 1e6
    derived = derived_fn(rows)
    print(f"\nCSV,{name},{dt:.0f},{derived}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller grids (CI-sized)")
    ap.add_argument("--skip-exact", action="store_true",
                    help="skip the exact-MILP Table II benchmark")
    args = ap.parse_args()

    from benchmarks import (fig5_profiles, fig6_slotlen, fig7_methods,
                            fig8_helpers, table2_admm)

    print("=" * 72)
    print("Fig. 5 — per-device part profiles (Table I calibration)")
    print("=" * 72)
    _timed("fig5_profiles", fig5_profiles.main,
           lambda rows: f"devices={len(rows)}")

    if not args.skip_exact:
        print("\n" + "=" * 72)
        print("Table II — ADMM vs exact ILP (HiGHS): suboptimality & speedup")
        print("=" * 72)
        _timed("table2_admm", table2_admm.main,
               lambda rows: "max_subopt_pct=" + str(max(
                   (r["suboptimality_pct"] for r in rows
                    if r["suboptimality_pct"] == r["suboptimality_pct"]),
                   default="nan")))

    print("\n" + "=" * 72)
    print("Fig. 6 — slot length vs makespan / solve time")
    print("=" * 72)
    _timed("fig6_slotlen", fig6_slotlen.main,
           lambda rows: f"mk_increase_200ms={rows[-1]['makespan_increase_pct']}%")

    print("\n" + "=" * 72)
    print("Fig. 7 — methods vs baseline across scenario sizes")
    print("=" * 72)
    _timed("fig7_methods", lambda: fig7_methods.main(fast=args.fast),
           lambda rows: "max_gain_pct=" + str(
               max(r["strategy_gain_pct"] for r in rows)))

    print("\n" + "=" * 72)
    print("Fig. 8 — makespan vs number of helpers (J=100)")
    print("=" * 72)
    _timed("fig8_helpers", fig8_helpers.main,
           lambda rows: "gain_1_to_2_helpers_pct=" + str(
               rows[1]["gain_vs_prev_pct"]))

    print("\n" + "=" * 72)
    print("Beyond-paper: cut-layer co-optimization + batch pipelining")
    print("=" * 72)
    from benchmarks import beyond_paper
    _timed("beyond_paper", beyond_paper.main,
           lambda rows: "cut_gain_pct=" + str(
               max(r.get("gain_pct", 0) for r in rows)))

    import os
    if os.path.isdir("experiments/dryrun"):
        from benchmarks import roofline_table
        print("\n" + "=" * 72)
        print("Roofline terms from the multi-pod dry-run")
        print("=" * 72)
        _timed("roofline_table", roofline_table.main,
               lambda rows: f"pairs={sum(1 for r in rows if not r.get('failed'))}")


if __name__ == "__main__":
    main()
