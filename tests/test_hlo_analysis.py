"""Trip-count-aware HLO analyzer: unit + closed-form integration tests."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import HloAnalyzer, analyze_text, parse_computations


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    txt = _compile_text(f, jnp.ones((64, 64)))
    t = analyze_text(txt)
    assert t.flops == pytest.approx(7 * 2 * 64 ** 3, rel=1e-6)


def test_nested_scan_trip_counts():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out
    txt = _compile_text(f, jnp.ones((32, 32)))
    t = analyze_text(txt)
    assert t.flops == pytest.approx(15 * 2 * 32 ** 3, rel=1e-6)


def test_plain_chain_exact():
    def g(a, b):
        return (a @ b) @ b
    txt = _compile_text(g, jnp.ones((16, 64)), jnp.ones((64, 64)))
    t = analyze_text(txt)
    assert t.flops == pytest.approx(2 * 16 * 64 * 64 * 2, rel=1e-6)


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    txt = _compile_text(f, jnp.ones((4, 8, 16)), jnp.ones((4, 16, 32)))
    t = analyze_text(txt)
    assert t.flops == pytest.approx(2 * 4 * 8 * 16 * 32, rel=1e-6)


def test_parse_computations_headers_and_instrs():
    txt = """
ENTRY %main.4 (x.1: f32[8,8]) -> f32[8,8] {
  %constant.5 = s32[] constant(0)
  ROOT %dot.1 = f32[8,8]{1,0} dot(%x.1, %x.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    comps = parse_computations(txt)
    assert "main.4" in comps
    comp = comps["main.4"]
    assert comp.is_entry
    ops = {i.opcode for i in comp.instrs}
    assert "dot" in ops and "constant" in ops
    t = analyze_text(txt)
    assert t.flops == 2 * 8 * 8 * 8


def test_tuple_shape_with_index_comment():
    """Regression: /*index=5*/ comments inside tuple shapes contain '='."""
    txt = """
ENTRY %main (a: f32[4]) -> f32[4] {
  %while.1 = (s32[], f32[4]{0}, /*index=2*/f32[4]{0}) while(%t), condition=%c, body=%b, backend_config={"known_trip_count":{"n":"9"}}
}
%b (arg: (s32[], f32[4])) -> (s32[], f32[4]) {
  %dot.2 = f32[]{} dot(%p, %p), lhs_contracting_dims={0}, rhs_contracting_dims={0}
}
"""
    comps = parse_computations(txt)
    an = HloAnalyzer(txt)
    assert an.trip.get("b") == 9
