"""Trip-count-aware HLO analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so anything
inside ``lax.scan`` (layer stacks, KV-block attention, microbatching) is
undercounted by its trip count. This module re-derives the roofline inputs
by parsing the scheduled HLO text into its computation graph:

  * per-computation matmul FLOPs (dot ops, contracting dims from the attrs),
  * an HBM-traffic proxy (result + operand bytes of non-layout ops at
    fusion granularity — fusion-internal values stay on-chip),
  * per-collective wire bytes (ring accounting over replica groups),

and aggregating ENTRY -> calls with ``while`` bodies multiplied by their
``backend_config known_trip_count`` (fallback: the largest s32 constant in
the loop condition).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([\w\-]+)\(")
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%([\w.\-]+)\s+\((.*)\)\s+->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")

_LAYOUT_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "reshape", "transpose", "broadcast", "iota",
               "after-all", "partition-id", "replica-id"}


def _shapes_of(txt: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(txt):
        dims = tuple(int(x) for x in m.group(2).split(",")) if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        b = _DTYPE_BYTES.get(dt, 0)
        n = 1
        for d in dims:
            n *= d
        total += n * b
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shapes: List[Tuple[str, Tuple[int, ...]]]
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    params: Dict[str, List[Tuple[str, Tuple[int, ...]]]]


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        h = _HEADER_RE.match(line)
        if h and line.rstrip().endswith("{"):
            params = {}
            for pm in re.finditer(
                    r"([\w.\-]+):\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)",
                    h.group(3)):
                params[pm.group(1)] = _shapes_of(pm.group(2))
            cur = Computation(name=h.group(2), is_entry=bool(h.group(1)),
                              instrs=[], params=params)
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shapes_txt, opcode = m.group(1), m.group(2), m.group(3)
        rest = line[m.end():]
        paren = rest.split("),", 1)
        operand_txt = paren[0]
        attrs = paren[1] if len(paren) > 1 else rest
        cur.instrs.append(Instr(
            name=name, shapes=_shapes_of(shapes_txt), opcode=opcode,
            operands=_OPERAND_RE.findall(operand_txt), attrs=attrs))
    return comps


def _group_size(attrs: str, default: int = 2) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        g = m.group(1)
        return max(len(g.split(",")) if g else 1, 1)
    return default


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    memory_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.memory_bytes += other.memory_bytes * mult
        for k in self.coll:
            self.coll[k] += other.coll[k] * mult


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_computations(text)
        self.fused = set()
        self.trip: Dict[str, int] = {}
        for comp in self.comps.values():
            for ins in comp.instrs:
                if ins.opcode == "fusion":
                    for cm in re.finditer(r"calls=%([\w.\-]+)", ins.attrs):
                        self.fused.add(cm.group(1))
                if ins.opcode == "while":
                    bm = re.search(r"body=%([\w.\-]+)", ins.attrs)
                    tm = _TRIP_RE.search(ins.attrs)
                    trip = int(tm.group(1)) if tm else None
                    if trip is None:
                        cm = re.search(r"condition=%([\w.\-]+)", ins.attrs)
                        trip = self._cond_trip(cm.group(1)) if cm else 1
                    if bm:
                        self.trip[bm.group(1)] = trip
        self._cache: Dict[str, Totals] = {}
        self.unresolved_dots = 0

    def _cond_trip(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for ins in comp.instrs:
            if ins.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", ins.attrs) or \
                    re.search(r"constant\((\d+)\)", str(ins.operands))
                # constants keep their value inside the original line; re-find:
        # fallback: scan raw attr text of all instrs
        for ins in comp.instrs:
            for m in re.finditer(r"constant\((\d+)\)", ins.attrs):
                best = max(best, int(m.group(1)))
        return best

    # -- shape resolution ---------------------------------------------------
    def _symbol_shapes(self, comp: Computation) -> Dict[str, List]:
        table: Dict[str, List] = dict(comp.params)
        for ins in comp.instrs:
            table[ins.name] = ins.shapes
        return table

    def _dot_flops(self, comp: Computation, ins: Instr,
                   table: Dict[str, List]) -> float:
        res_elems = 1
        for _, dims in ins.shapes:
            for d in dims:
                res_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
        lhs = table.get(ins.operands[0]) if ins.operands else None
        if not m or not lhs or not lhs[0][1]:
            self.unresolved_dots += 1
            return 0.0
        cdims = [int(x) for x in m.group(1).split(",")] if m.group(1) else []
        k = 1
        for c in cdims:
            if c < len(lhs[0][1]):
                k *= lhs[0][1][c]
        # batch dims are part of the result; 2*M*N*K*B accounting
        return 2.0 * res_elems * k

    # -- aggregation ----------------------------------------------------------
    def totals(self, comp_name: str) -> Totals:
        if comp_name in self._cache:
            return self._cache[comp_name]
        comp = self.comps.get(comp_name)
        t = Totals()
        if comp is None:
            self._cache[comp_name] = t
            return t
        self._cache[comp_name] = t  # break cycles defensively
        table = self._symbol_shapes(comp)
        in_fused = comp_name in self.fused
        for ins in comp.instrs:
            if ins.opcode == "dot":
                t.flops += self._dot_flops(comp, ins, table)
            elif ins.opcode in _COLLECTIVES or any(
                    ins.opcode == c + "-start" for c in _COLLECTIVES):
                base = ins.opcode.replace("-start", "")
                size = _bytes_of(ins.shapes)
                g = _group_size(ins.attrs)
                if base == "all-reduce":
                    wire = 2.0 * (g - 1) / g * size
                elif base in ("all-gather", "all-to-all"):
                    wire = (g - 1) / g * size
                elif base == "reduce-scatter":
                    wire = (g - 1) * size
                else:
                    wire = size
                t.coll[base] += wire
                t.memory_bytes += size
            elif ins.opcode == "fusion":
                callee = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                if callee:
                    sub = self.totals(callee.group(1))
                    t.flops += sub.flops
                    for k in t.coll:
                        t.coll[k] += sub.coll[k]
                # memory at fusion granularity: result + operand bytes
                t.memory_bytes += _bytes_of(ins.shapes)
                for op in ins.operands:
                    t.memory_bytes += _bytes_of(table.get(op, []))
            elif ins.opcode == "while":
                bm = re.search(r"body=%([\w.\-]+)", ins.attrs)
                cm = re.search(r"condition=%([\w.\-]+)", ins.attrs)
                if bm:
                    trip = self.trip.get(bm.group(1), 1)
                    t.add(self.totals(bm.group(1)), trip)
                if cm:
                    t.add(self.totals(cm.group(1)), 1.0)
            elif ins.opcode in ("call", "conditional", "custom-call",
                                "async-start"):
                for cm in re.finditer(
                        r"(?:to_apply|calls|branch_computations=\{?)=?%([\w.\-]+)",
                        ins.attrs):
                    t.add(self.totals(cm.group(1)), 1.0)
                t.memory_bytes += _bytes_of(ins.shapes)
            elif ins.opcode in _LAYOUT_OPS:
                continue
            elif ins.opcode in ("dynamic-slice", "gather"):
                # reads only the slice, not the whole buffer
                t.memory_bytes += 2 * _bytes_of(ins.shapes)
            elif ins.opcode in ("dynamic-update-slice", "scatter"):
                # writes only the update (operand 1), aliased in place
                upd = (table.get(ins.operands[1], [])
                       if len(ins.operands) > 1 else [])
                t.memory_bytes += 2 * _bytes_of(upd)
            else:
                if not in_fused:
                    # standalone op: results + operands move through HBM
                    t.memory_bytes += _bytes_of(ins.shapes)
                    for op in ins.operands:
                        t.memory_bytes += _bytes_of(table.get(op, []))
                else:
                    if ins.opcode == "dot":
                        pass  # handled above
        return t

    def entry_totals(self) -> Totals:
        for name, comp in self.comps.items():
            if comp.is_entry:
                return self.totals(name)
        raise ValueError("no ENTRY computation found")


def analyze_text(text: str) -> Totals:
    return HloAnalyzer(text).entry_totals()
