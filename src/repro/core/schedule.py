"""Schedule representation + feasibility evaluation (constraints (1)-(9)).

A schedule stores, per client, the assigned helper and the *sorted slot lists*
where its fwd-prop (x) and bwd-prop (z) tasks occupy the helper. The sparse
representation keeps memory at O(total processing time) instead of O(|E| T).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from .instance import Instance


@dataclasses.dataclass
class Schedule:
    assign: np.ndarray  # [J] helper index per client (y)
    x_slots: List[np.ndarray]  # [J] sorted slot indices of fwd-prop on assigned helper
    z_slots: List[np.ndarray]  # [J] sorted slot indices of bwd-prop on assigned helper

    def phi_f(self, j: int) -> int:
        """Fwd-prop finish slot (phi^f_j): last fwd slot + 1 (end of S_t)."""
        return int(self.x_slots[j][-1]) + 1 if len(self.x_slots[j]) else 0

    def phi(self, j: int) -> int:
        """Bwd-prop finish slot (phi_j)."""
        return int(self.z_slots[j][-1]) + 1 if len(self.z_slots[j]) else 0

    def completion_fwd(self, inst: Instance, j: int) -> int:
        """c^f_j = phi^f_j + l_{ij} (13)."""
        i = int(self.assign[j])
        return self.phi_f(j) + int(inst.l[i, j])

    def completion(self, inst: Instance, j: int) -> int:
        """c_j = phi_j + r'_{ij} (9)."""
        i = int(self.assign[j])
        return self.phi(j) + int(inst.rp[i, j])

    def makespan(self, inst: Instance) -> int:
        """max_j c_j — the batch training makespan (Problem 1 objective)."""
        return max(self.completion(inst, j) for j in range(inst.J))

    def fwd_makespan(self, inst: Instance) -> int:
        """max_j c^f_j — the P_f objective."""
        return max(self.completion_fwd(inst, j) for j in range(inst.J))

    def num_preemptions(self, j: int) -> int:
        """Count task switches for client j (gaps inside x/z slot runs)."""
        n = 0
        for slots in (self.x_slots[j], self.z_slots[j]):
            if len(slots) > 1:
                n += int(np.sum(np.diff(slots) > 1))
        return n

    def makespan_with_preemption_cost(self, inst: Instance) -> float:
        """Sec. VI extension: each task switch at helper i costs mu_i slots.

        The switching penalty is charged to the client whose task is split,
        matching the modified (13): c_j includes mu_i * (#switch boundaries of
        its x/z runs).
        """
        if inst.mu is None:
            return float(self.makespan(inst))
        worst = 0.0
        for j in range(inst.J):
            i = int(self.assign[j])
            switches = 0
            for slots in (self.x_slots[j], self.z_slots[j]):
                if len(slots) == 0:
                    continue
                # |x_t - x_{t+1}| summed over t counts 2 per run (start+stop);
                # a task "just started" costs one switch, so runs == switches.
                runs = 1 + int(np.sum(np.diff(slots) > 1))
                switches += runs
            worst = max(worst, self.completion(inst, j) + float(inst.mu[i]) * switches)
        return worst


class InfeasibleScheduleError(AssertionError):
    pass


def check_feasible(inst: Instance, sched: Schedule, *, horizon: Optional[int] = None) -> None:
    """Verify constraints (1)-(9). Raises InfeasibleScheduleError on violation."""
    T = horizon if horizon is not None else inst.T
    busy: Dict[int, Dict[int, int]] = {i: {} for i in range(inst.I)}  # helper -> slot -> client

    for j in range(inst.J):
        i = int(sched.assign[j])
        if not inst.is_edge(i, j):
            raise InfeasibleScheduleError(f"client {j} assigned to non-neighbor helper {i}")
        xs, zs = sched.x_slots[j], sched.z_slots[j]
        # (6), (7): exactly p_ij fwd slots and p'_ij bwd slots on assigned helper
        if len(xs) != inst.p[i, j]:
            raise InfeasibleScheduleError(
                f"client {j}: {len(xs)} fwd slots != p={inst.p[i, j]}")
        if len(zs) != inst.pp[i, j]:
            raise InfeasibleScheduleError(
                f"client {j}: {len(zs)} bwd slots != p'={inst.pp[i, j]}")
        # (1): release time
        if xs[0] < inst.r[i, j]:
            raise InfeasibleScheduleError(
                f"client {j}: fwd starts at {xs[0]} before release r={inst.r[i, j]}")
        # (2): bwd-prop precedence — first bwd slot >= phi^f + l + l'
        ready = sched.phi_f(j) + int(inst.l[i, j]) + int(inst.lp[i, j])
        if zs[0] < ready:
            raise InfeasibleScheduleError(
                f"client {j}: bwd starts at {zs[0]} before ready time {ready}")
        for slots in (xs, zs):
            if np.any(np.diff(slots) <= 0):
                raise InfeasibleScheduleError(f"client {j}: slots not strictly increasing")
            if slots[-1] >= T:
                raise InfeasibleScheduleError(
                    f"client {j}: slot {slots[-1]} beyond horizon T={T}")
            for t in slots:
                t = int(t)
                # (3): one task per helper per slot
                if t in busy[i]:
                    raise InfeasibleScheduleError(
                        f"helper {i} double-booked at slot {t} "
                        f"(clients {busy[i][t]} and {j})")
                busy[i][t] = j

    # (5): helper memory
    for i in range(inst.I):
        load = sum(inst.d[j] for j in range(inst.J) if sched.assign[j] == i)
        if load > inst.m[i] + 1e-9:
            raise InfeasibleScheduleError(
                f"helper {i}: memory {load:.3f} > capacity {inst.m[i]:.3f}")


def queuing_delay(inst: Instance, sched: Schedule, j: int) -> int:
    """phi_j - (r + p + l + l' + p') — the client's total queuing delay (Sec. IV)."""
    i = int(sched.assign[j])
    ideal = int(inst.r[i, j] + inst.p[i, j] + inst.l[i, j] + inst.lp[i, j] + inst.pp[i, j])
    return sched.phi(j) - ideal


def lower_bound(inst: Instance) -> int:
    """A simple valid lower bound on the optimal makespan.

    LB = max over clients of the no-queue critical path on their *best*
    feasible helper, and per-helper load bounds under any assignment.
    """
    # per-client critical path on best helper
    best_path = 0
    for j in range(inst.J):
        paths = [
            int(inst.r[i, j] + inst.p[i, j] + inst.l[i, j]
                + inst.lp[i, j] + inst.pp[i, j] + inst.rp[i, j])
            for i in inst.feasible_helpers(j)
        ]
        best_path = max(best_path, min(paths))
    # machine-load bound: even a perfect split must process sum of min work
    total_min_work = sum(
        min(int(inst.p[i, j] + inst.pp[i, j]) for i in inst.feasible_helpers(j))
        for j in range(inst.J)
    )
    load_bound = -(-total_min_work // inst.I)  # ceil
    return max(best_path, load_bound)
