"""Step builders for the production launcher: train_step / prefill_step /
serve_step with full sharding annotations, plus abstract ``input_specs`` for
the dry-run (ShapeDtypeStruct only — no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.transformer import (Runtime, abstract_params, forward,
                                      init_caches, layer_table, loss_fn,
                                      serve_step)
from repro.optim.adam import Adam
from .mesh import auto_pspec, batch_sharding, fsdp_axes, param_shardings


def make_runtime(cfg: ModelConfig, mesh: Mesh, kind: str,
                 long_context: bool = False, *,
                 moe_override: Optional[str] = None,
                 remat: bool = True,
                 scan_layers: bool = True,
                 seq_parallel_attn: bool = False) -> Runtime:
    multi_pod = "pod" in mesh.axis_names
    if cfg.moe is None:
        moe_mode = "dense"
    elif moe_override is not None:
        moe_mode = moe_override
    else:
        moe_mode = "ep_local" if kind == "decode" else "ep_a2a"
    return Runtime(
        scan_layers=scan_layers and kind != "decode",
        moe_mode=moe_mode,
        mesh=mesh,
        data_axes=fsdp_axes(multi_pod),
        model_axis="model",
        long_context=long_context,
        remat=remat and kind == "train",
        fsdp_gather=True,
        seq_parallel_attn=seq_parallel_attn,
    )


# --------------------------------------------------------------------------
# Abstract inputs (dry-run)
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input. Weak-type-correct,
    shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            batch["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


def cache_specs(cfg: ModelConfig, shape: InputShape, rt: Runtime):
    return init_caches(cfg, shape.global_batch, shape.seq_len, rt,
                       dtype=jnp.bfloat16, abstract=True)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                    rt: Runtime):
    """Shard caches: batch over fsdp axes; kv-heads / ssm-heads over model.
    For batch=1 long-context, the sequence axis takes the fsdp axes."""
    multi_pod = "pod" in mesh.axis_names
    fsdp = fsdp_axes(multi_pod)
    B = shape.global_batch
    batch_ok = B % int(jnp.prod(jnp.array([mesh.shape[a] for a in fsdp]))) == 0

    model_size = mesh.shape["model"]

    def shard(leaf):
        s = leaf.shape
        wanted = [None] * len(s)
        if batch_ok:
            wanted[0] = fsdp
        elif len(s) >= 2 and s[1] > 1024:  # seq-shard long caches
            wanted[1] = fsdp
        if len(s) == 4:   # [B, S|W, KV, D] or ssm [B, H, P, N]
            if s[2] % model_size == 0:
                wanted[2] = "model"
            elif wanted[1] is None and s[1] % model_size == 0:
                # KV heads cannot shard over the model axis (e.g. 8 kv heads
                # on 16-way TP): shard the SEQUENCE dim instead — otherwise
                # the cache replicates per device (nemotron decode: 158 GB!)
                wanted[1] = "model"
        return NamedSharding(mesh, auto_pspec(s, wanted, mesh))

    caches = cache_specs(cfg, shape, rt)
    return jax.tree.map(shard, caches)


# --------------------------------------------------------------------------
# Step functions
# --------------------------------------------------------------------------
def build_train_step(cfg: ModelConfig, mesh: Mesh, rt: Runtime,
                     optimizer: Optional[Adam] = None,
                     microbatches: int = 1):
    """With ``microbatches > 1`` the global batch is split along axis 0 and
    gradients are accumulated with lax.scan — the standard activation-memory
    lever (perf-iteration knob)."""
    opt = optimizer or Adam(lr=1e-4)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, rt), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_step(carry, b):
                (loss_a, aux_a, g_a) = carry
                (l, m), g = grads_of(params, b)
                g2 = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                  g_a, g)
                return (loss_a + l, aux_a + m["aux"], g2), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_s, aux_s, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), jnp.zeros(()), zeros), mb)
            loss = loss_s / microbatches
            metrics = {"ce": loss, "aux": aux_s / microbatches}
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss, **metrics}

    return train_step, opt


def build_prefill_step(cfg: ModelConfig, rt: Runtime):
    def prefill_step(params, batch):
        logits, _ = forward(cfg, params, batch, rt)
        # return only the last-position logits (the serving interface)
        return logits[:, -1]
    return prefill_step


def build_decode_step(cfg: ModelConfig, rt: Runtime):
    def decode_step(params, caches, batch):
        logits, new_caches = serve_step(cfg, params, caches,
                                        batch["tokens"], batch["pos"], rt)
        return logits, new_caches
    return decode_step


# --------------------------------------------------------------------------
# Dry-run lowering for one (arch x shape x mesh)
# --------------------------------------------------------------------------
def lower_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
               *, rt_overrides: Optional[dict] = None,
               rules: Optional[dict] = None, microbatches: int = 1):
    """Lower (not compile) the appropriate step. Returns (lowered, meta)."""
    overrides = dict(rt_overrides or {})
    long_ctx = overrides.pop(
        "long_context",
        shape.kind == "decode" and shape.seq_len > 100_000)
    rt = make_runtime(cfg, mesh, shape.kind, long_context=long_ctx,
                      **overrides)
    pshard = param_shardings(cfg, mesh, rules=rules)
    params_abs = abstract_params(cfg, jnp.bfloat16)
    bshard_fn = batch_sharding(mesh)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        from repro.optim.adam import AdamState
        step, opt = build_train_step(cfg, mesh, rt, microbatches=microbatches)
        opt_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_abs)
        opt_state_abs = AdamState(step=jax.ShapeDtypeStruct((), jnp.int32),
                                  m=opt_abs, v=opt_abs)
        opt_shard = AdamState(step=NamedSharding(mesh, P()),
                              m=pshard, v=pshard)
        bshard = jax.tree.map(lambda s: bshard_fn(len(s.shape)), specs)
        jitted = jax.jit(step,
                         in_shardings=(pshard, opt_shard, bshard),
                         out_shardings=(pshard, opt_shard,
                                        NamedSharding(mesh, P())),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_state_abs, specs)
        return lowered, {"kind": "train"}

    if shape.kind == "prefill":
        step = build_prefill_step(cfg, rt)
        bshard = jax.tree.map(lambda s: bshard_fn(len(s.shape)), specs)
        jitted = jax.jit(step, in_shardings=(pshard, bshard),
                         out_shardings=bshard_fn(2))
        lowered = jitted.lower(params_abs, specs)
        return lowered, {"kind": "prefill"}

    # decode
    step = build_decode_step(cfg, rt)
    cshard = cache_shardings(cfg, mesh, shape, rt)
    caches_abs = cache_specs(cfg, shape, rt)
    B = shape.global_batch
    tok_shard = (bshard_fn(2) if B > 1 else NamedSharding(mesh, P(None, None)))
    multi_pod = "pod" in mesh.axis_names
    logit_wanted = ([fsdp_axes(multi_pod), None, None] if B > 1
                    else [None, None, "model"])
    logit_shard = NamedSharding(
        mesh, auto_pspec((B, 1, cfg.vocab_size), logit_wanted, mesh))
    bshard = {"tokens": tok_shard, "pos": NamedSharding(mesh, P())}
    jitted = jax.jit(step,
                     in_shardings=(pshard, cshard, bshard),
                     out_shardings=(logit_shard, cshard),
                     donate_argnums=(1,))
    lowered = jitted.lower(params_abs, caches_abs,
                           {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                            "pos": jax.ShapeDtypeStruct((), jnp.int32)})
    return lowered, {"kind": "decode"}
