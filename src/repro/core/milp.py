"""Exact ILP formulations of Problem 1 (P), P_f, and the ADMM subproblems.

The paper uses Gurobi; offline we use ``scipy.optimize.milp`` (HiGHS
branch-and-cut), which is exact. Variables follow Sec. III/IV:

  x_ijt, z_ijt in {0,1}   fwd / bwd processing indicators
  y_ij in {0,1}           assignment
  phi_j, c_j              finish / completion times
  xi                      epigraph variable for the min-max objective
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
from scipy import sparse
from scipy.optimize import LinearConstraint, Bounds, milp

from .instance import Instance
from .schedule import Schedule


@dataclasses.dataclass
class MilpResult:
    schedule: Optional[Schedule]
    objective: float
    status: str
    mip_gap: float
    runtime_s: float


class _Builder:
    """Tiny sparse MILP builder: named variable groups + triplet constraints."""

    def __init__(self):
        self.n = 0
        self.groups: Dict[str, Tuple[int, tuple]] = {}
        self.lb: List[float] = []
        self.ub: List[float] = []
        self.integrality: List[int] = []
        self.obj: Dict[int, float] = {}
        self.rows: List[Tuple[Dict[int, float], float, float]] = []

    def add_group(self, name: str, shape: tuple, *, lb=0.0, ub=1.0, integer=True) -> None:
        size = int(np.prod(shape))
        self.groups[name] = (self.n, shape)
        self.n += size
        self.lb += [lb] * size
        self.ub += [ub] * size
        self.integrality += [1 if integer else 0] * size

    def idx(self, name: str, *index) -> int:
        start, shape = self.groups[name]
        return start + int(np.ravel_multi_index(index, shape))

    def set_obj(self, var: int, coef: float) -> None:
        self.obj[var] = self.obj.get(var, 0.0) + coef

    def add_row(self, coefs: Dict[int, float], lo: float, hi: float) -> None:
        self.rows.append((coefs, lo, hi))

    def solve(self, *, time_limit: Optional[float] = None, mip_rel_gap: float = 0.0):
        import time as _time

        c = np.zeros(self.n)
        for k, v in self.obj.items():
            c[k] = v
        data, ri, ci = [], [], []
        lo = np.empty(len(self.rows))
        hi = np.empty(len(self.rows))
        for rn, (coefs, a, b) in enumerate(self.rows):
            lo[rn], hi[rn] = a, b
            for k, v in coefs.items():
                ri.append(rn)
                ci.append(k)
                data.append(v)
        A = sparse.csr_matrix((data, (ri, ci)), shape=(len(self.rows), self.n))
        opts = {"mip_rel_gap": mip_rel_gap, "presolve": True}
        if time_limit is not None:
            opts["time_limit"] = time_limit
        t0 = _time.perf_counter()
        res = milp(
            c=c,
            constraints=LinearConstraint(A, lo, hi),
            bounds=Bounds(np.array(self.lb), np.array(self.ub)),
            integrality=np.array(self.integrality),
            options=opts,
        )
        return res, _time.perf_counter() - t0


def _extract_schedule(inst: Instance, bld: _Builder, xvec: np.ndarray,
                      T: int, with_z: bool) -> Schedule:
    assign = np.full(inst.J, -1, dtype=np.int64)
    for i in range(inst.I):
        for j in range(inst.J):
            if not inst.is_edge(i, j):
                continue
            if xvec[bld.idx("y", i, j)] > 0.5:
                assign[j] = i
    x_slots, z_slots = [], []
    for j in range(inst.J):
        i = int(assign[j])
        xs = [t for t in range(T) if inst.is_edge(i, j)
              and xvec[bld.idx("x", i, j, t)] > 0.5]
        x_slots.append(np.array(sorted(xs), dtype=np.int64))
        if with_z:
            zs = [t for t in range(T) if xvec[bld.idx("z", i, j, t)] > 0.5]
            z_slots.append(np.array(sorted(zs), dtype=np.int64))
        else:
            z_slots.append(np.array([], dtype=np.int64))
    return Schedule(assign=assign, x_slots=x_slots, z_slots=z_slots)


def solve_exact(inst: Instance, *, time_limit: Optional[float] = None,
                mip_rel_gap: float = 0.0, horizon: Optional[int] = None) -> MilpResult:
    """Exact solution of Problem 1 (the paper's Gurobi reference point)."""
    T = int(horizon if horizon is not None else inst.T)
    b = _Builder()
    b.add_group("x", (inst.I, inst.J, T))
    b.add_group("z", (inst.I, inst.J, T))
    b.add_group("y", (inst.I, inst.J))
    b.add_group("phi", (inst.J,), ub=T, integer=False)
    b.add_group("c", (inst.J,), ub=2 * T, integer=False)
    b.add_group("xi", (1,), ub=2 * T, integer=False)
    b.set_obj(b.idx("xi", 0), 1.0)

    for j in range(inst.J):
        # xi >= c_j (epigraph)
        b.add_row({b.idx("xi", 0): 1.0, b.idx("c", j): -1.0}, 0.0, np.inf)
        # (4): sum_i y_ij = 1
        b.add_row({b.idx("y", i, j): 1.0 for i in range(inst.I) if inst.is_edge(i, j)},
                  1.0, 1.0)
        # (9): c_j = phi_j + sum_i r'_ij y_ij
        row = {b.idx("c", j): 1.0, b.idx("phi", j): -1.0}
        for i in range(inst.I):
            if inst.is_edge(i, j):
                row[b.idx("y", i, j)] = -float(inst.rp[i, j])
        b.add_row(row, 0.0, 0.0)

    for i in range(inst.I):
        # (5): memory
        row = {b.idx("y", i, j): float(inst.d[j])
               for j in range(inst.J) if inst.is_edge(i, j)}
        if row:
            b.add_row(row, -np.inf, float(inst.m[i]))
        # (3): single task per slot
        for t in range(T):
            row = {}
            for j in range(inst.J):
                if inst.is_edge(i, j):
                    row[b.idx("x", i, j, t)] = 1.0
                    row[b.idx("z", i, j, t)] = 1.0
            if row:
                b.add_row(row, -np.inf, 1.0)

    for i in range(inst.I):
        for j in range(inst.J):
            if not inst.is_edge(i, j):
                # forbid x,z,y on non-edges
                for t in range(T):
                    b.ub[b.idx("x", i, j, t)] = 0.0
                    b.ub[b.idx("z", i, j, t)] = 0.0
                b.ub[b.idx("y", i, j)] = 0.0
                continue
            # (1): release times
            for t in range(min(int(inst.r[i, j]), T)):
                b.ub[b.idx("x", i, j, t)] = 0.0
            # (6), (7): processing totals tied to assignment
            b.add_row({**{b.idx("x", i, j, t): 1.0 for t in range(T)},
                       b.idx("y", i, j): -float(inst.p[i, j])}, 0.0, 0.0)
            b.add_row({**{b.idx("z", i, j, t): 1.0 for t in range(T)},
                       b.idx("y", i, j): -float(inst.pp[i, j])}, 0.0, 0.0)
            # (2): precedence z_{ij,t+l+l'} <= (1/p) sum_{tau<t} x
            off = int(inst.l[i, j] + inst.lp[i, j])
            # slots below the offset are unreachable by (2)'s index shift;
            # they are infeasible by definition (bwd before any fwd+l+l')
            earliest_z = int(inst.r[i, j] + inst.p[i, j]) + off
            for t in range(min(earliest_z, T)):
                b.ub[b.idx("z", i, j, t)] = 0.0
            for t in range(T):
                tz = t + off
                if tz >= T:
                    break
                row = {b.idx("z", i, j, tz): 1.0}
                for tau in range(t):
                    row[b.idx("x", i, j, tau)] = -1.0 / float(inst.p[i, j])
                b.add_row(row, -np.inf, 0.0)
            # (8): phi_j >= (t+1) z_ijt
            for t in range(T):
                b.add_row({b.idx("phi", j): 1.0,
                           b.idx("z", i, j, t): -float(t + 1)}, 0.0, np.inf)

    res, rt = b.solve(time_limit=time_limit, mip_rel_gap=mip_rel_gap)
    if res.x is None:
        return MilpResult(None, float("inf"), res.message, float("nan"), rt)
    sched = _extract_schedule(inst, b, res.x, T, with_z=True)
    gap = float(getattr(res, "mip_gap", 0.0) or 0.0)
    return MilpResult(sched, float(res.fun), "optimal" if gap <= 1e-6 else "feasible",
                      gap, rt)


def solve_w_subproblem(
    inst: Instance,
    y: np.ndarray,
    lam: np.ndarray,
    rho: float,
    *,
    time_limit: Optional[float] = None,
    horizon: Optional[int] = None,
) -> Tuple[Schedule, float]:
    """Exact w-step of Algorithm 1 (line 2): min L over x, phi^f, c^f.

    Constraints: (1), (12)-(15), (20). ``y`` is [I, J] binary; ``lam`` is
    [I, J]. Returns (fwd-only Schedule, objective value).
    """
    Tf = int(horizon if horizon is not None else inst.T_f)
    b = _Builder()
    b.add_group("x", (inst.I, inst.J, Tf))
    b.add_group("phif", (inst.J,), ub=Tf, integer=False)
    b.add_group("cf", (inst.J,), ub=2 * Tf, integer=False)
    b.add_group("xi", (1,), ub=2 * Tf, integer=False)
    b.add_group("u", (inst.I, inst.J), ub=Tf, integer=False)  # |sum x - y p|
    b.set_obj(b.idx("xi", 0), 1.0)

    for j in range(inst.J):
        b.add_row({b.idx("xi", 0): 1.0, b.idx("cf", j): -1.0}, 0.0, np.inf)
        # (13) with y fixed: c^f_j = phi^f_j + l_{y_j, j}
        i_assigned = int(np.argmax(y[:, j])) if y[:, j].max() > 0 else None
        l_j = float(inst.l[i_assigned, j]) if i_assigned is not None else 0.0
        b.add_row({b.idx("cf", j): 1.0, b.idx("phif", j): -1.0}, l_j, l_j)
        # (20): total processing across helpers sums to one task
        row = {}
        for i in range(inst.I):
            if inst.is_edge(i, j):
                for t in range(Tf):
                    row[b.idx("x", i, j, t)] = 1.0 / float(inst.p[i, j])
        b.add_row(row, 1.0, 1.0)

    for i in range(inst.I):
        for t in range(Tf):
            row = {b.idx("x", i, j, t): 1.0
                   for j in range(inst.J) if inst.is_edge(i, j)}
            if row:
                b.add_row(row, -np.inf, 1.0)  # (14)

    for i in range(inst.I):
        for j in range(inst.J):
            if not inst.is_edge(i, j):
                for t in range(Tf):
                    b.ub[b.idx("x", i, j, t)] = 0.0
                continue
            for t in range(min(int(inst.r[i, j]), Tf)):
                b.ub[b.idx("x", i, j, t)] = 0.0  # (1)
            for t in range(Tf):
                b.add_row({b.idx("phif", j): 1.0,
                           b.idx("x", i, j, t): -float(t + 1)}, 0.0, np.inf)  # (12)
            # lagrangian terms: lam_ij * sum_t x_ijt  (the -lam y p part is const)
            for t in range(Tf):
                b.set_obj(b.idx("x", i, j, t), float(lam[i, j]))
            # u_ij >= +/- (sum_t x_ijt - y_ij p_ij)
            target = float(y[i, j]) * float(inst.p[i, j])
            row = {b.idx("u", i, j): 1.0}
            for t in range(Tf):
                row[b.idx("x", i, j, t)] = -1.0
            b.add_row(row, -target, np.inf)
            row = {b.idx("u", i, j): 1.0}
            for t in range(Tf):
                row[b.idx("x", i, j, t)] = 1.0
            b.add_row(row, target, np.inf)
            b.set_obj(b.idx("u", i, j), rho / 2.0)

    res, _ = b.solve(time_limit=time_limit, mip_rel_gap=1e-4)
    if res.x is None:
        raise RuntimeError(f"w-subproblem infeasible: {res.message}")
    # extract: fwd slots per (i, j); a client may be split across helpers here
    assign = np.full(inst.J, -1, dtype=np.int64)
    x_slots = []
    for j in range(inst.J):
        per_helper = {}
        for i in range(inst.I):
            if not inst.is_edge(i, j):
                continue
            s = [t for t in range(Tf) if res.x[b.idx("x", i, j, t)] > 0.5]
            if s:
                per_helper[i] = s
        # dominant helper = the one doing most work (used for c^f accounting)
        if per_helper:
            dom = max(per_helper, key=lambda k: len(per_helper[k]))
        else:
            dom = 0
        assign[j] = dom
        allslots = sorted(t for s in per_helper.values() for t in s)
        x_slots.append(np.array(allslots, dtype=np.int64))
    sched = Schedule(assign=assign, x_slots=x_slots,
                     z_slots=[np.array([], dtype=np.int64)] * inst.J)
    return sched, float(res.fun)


def solve_y_subproblem(
    inst: Instance,
    x_totals: np.ndarray,
    lam: np.ndarray,
    rho: float,
) -> np.ndarray:
    """Exact y-step of Algorithm 1 (line 3): generalized assignment MILP.

    With x fixed, the Lagrangian is linear in y:
      cost(y_ij=1) - cost(y_ij=0) =
        -lam_ij p_ij + rho/2 (|X_ij - p_ij| - X_ij).
    """
    b = _Builder()
    b.add_group("y", (inst.I, inst.J))
    for i in range(inst.I):
        for j in range(inst.J):
            if not inst.is_edge(i, j):
                b.ub[b.idx("y", i, j)] = 0.0
                continue
            X = float(x_totals[i, j])
            w = (-float(lam[i, j]) * float(inst.p[i, j])
                 + (rho / 2.0) * (abs(X - float(inst.p[i, j])) - X))
            b.set_obj(b.idx("y", i, j), w)
    for j in range(inst.J):
        b.add_row({b.idx("y", i, j): 1.0
                   for i in range(inst.I) if inst.is_edge(i, j)}, 1.0, 1.0)
    for i in range(inst.I):
        row = {b.idx("y", i, j): float(inst.d[j])
               for j in range(inst.J) if inst.is_edge(i, j)}
        if row:
            b.add_row(row, -np.inf, float(inst.m[i]))
    res, _ = b.solve()
    if res.x is None:
        raise RuntimeError(f"y-subproblem infeasible: {res.message}")
    y = np.zeros((inst.I, inst.J), dtype=np.int64)
    for i in range(inst.I):
        for j in range(inst.J):
            if inst.is_edge(i, j) and res.x[b.idx("y", i, j)] > 0.5:
                y[i, j] = 1
    return y
