"""Fig. 7 reproduction: ADMM-based vs balanced-greedy vs baseline
(random+FCFS) across scenario sizes, both models and heterogeneity levels.
Also evaluates the beyond-paper local-search refiner (reported separately)."""

from __future__ import annotations

import numpy as np

from repro.core import (solve_admm, solve_balanced_greedy, solve_baseline,
                        solve_local_search)
from repro.profiling.scenarios import cnn_instance

GRID = [(10, 2), (20, 3), (30, 5), (50, 5), (70, 10), (100, 10)]


def run(models=("resnet101", "vgg19"), scenarios=(1, 2), seeds=(0, 1, 2),
        grid=GRID, with_local_search: bool = True):
    rows = []
    for model in models:
        for sc in scenarios:
            for J, I in grid:
                mk = {"admm": [], "greedy": [], "baseline": [], "ls": []}
                for seed in seeds:
                    inst = cnn_instance(model, J=J, I=I, scenario=sc, seed=seed)
                    mk["greedy"].append(solve_balanced_greedy(inst).makespan)
                    mk["baseline"].append(np.mean(
                        [solve_baseline(inst, seed=s).makespan
                         for s in range(3)]))
                    a = solve_admm(inst, mode="fast",
                                   tau_max=8 if J <= 50 else 4)
                    mk["admm"].append(a.makespan)
                    if with_local_search:
                        ls = solve_local_search(
                            inst, init=a.schedule.assign.copy(),
                            time_budget_s=3.0 if J <= 50 else 1.0)
                        mk["ls"].append(ls.makespan)
                row = {"model": model, "scenario": sc, "J": J, "I": I}
                for k in mk:
                    if mk[k]:
                        row[k] = round(float(np.mean(mk[k])), 1)
                strat = min(row["admm"], row["greedy"])
                row["strategy_gain_pct"] = round(
                    100.0 * (row["baseline"] - strat) / row["baseline"], 1)
                if "ls" in row:
                    row["ls_gain_pct"] = round(
                        100.0 * (row["baseline"] - row["ls"]) / row["baseline"], 1)
                rows.append(row)
    return rows


def main(fast: bool = False):
    grid = GRID[:4] if fast else GRID
    rows = run(grid=grid, seeds=(0, 1) if fast else (0, 1, 2))
    print(f"{'model':10s} sc   J   I     admm   greedy baseline      ls  "
          f"gain%  ls_gain%")
    for r in rows:
        print(f"{r['model']:10s} {r['scenario']:2d} {r['J']:3d} {r['I']:3d} "
              f"{r['admm']:8.1f} {r['greedy']:8.1f} {r['baseline']:8.1f} "
              f"{r.get('ls', float('nan')):7.1f} {r['strategy_gain_pct']:6.1f} "
              f"{r.get('ls_gain_pct', float('nan')):9.1f}")
    gains = [r["strategy_gain_pct"] for r in rows]
    print(f"\nstrategy vs baseline: max gain {max(gains):.1f}%, "
          f"mean {np.mean(gains):.1f}%")
    return rows


if __name__ == "__main__":
    main()
