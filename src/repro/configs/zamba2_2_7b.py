"""Zamba2-2.7B [arXiv:2411.15242]: Mamba2 backbone with a SHARED
attention+MLP block interleaved every 6th position (weights reused)."""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("mamba",) * 5 + ("shared_attn",),
    mlp_kind="gelu",
    ssm=SSMConfig(state_size=64, conv_kernel=4, expand=2, ssm_head_dim=64),
    sliding_window=4096,  # used only for the long_500k adaptation (DESIGN.md)
    rope_theta=10000.0,
    tie_embeddings=True,
    sl_cut=(2, 52),
)
