"""Mamba2-130M [arXiv:2405.21060]: attention-free SSD (state-space duality)."""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    num_heads=0,       # attention-free
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,            # Mamba2 blocks have no separate MLP
    vocab_size=50280,
    block_pattern=("mamba",),
    mlp_kind="gelu",   # unused (d_ff=0)
    ssm=SSMConfig(state_size=128, conv_kernel=4, expand=2, ssm_head_dim=64),
    tie_embeddings=True,
    sl_cut=(2, 22),
)
