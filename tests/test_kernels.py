"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the exact TPU program body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import flash_attention_op, ssd_op
from repro.kernels.ref import flash_attention_ref, ssd_ref
from repro.models.attention import multi_head_attention


def _mk_qkv(key, B, Sq, Sk, H, KV, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), dtype)
    return q, k, v


@pytest.mark.parametrize("Sq,Sk", [(128, 128), (200, 200), (64, 256), (33, 65)])
@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2), (4, 1)])
def test_flash_shapes(Sq, Sk, H, KV):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), 2, Sq, Sk, H, KV, 64, jnp.float32)
    out = flash_attention_op(q, k, v, causal=True, block_q=64, block_k=64)
    qf = q.transpose(0, 2, 1, 3).reshape(2 * H, Sq, 64)
    kf = k.transpose(0, 2, 1, 3).reshape(2 * KV, Sk, 64)
    vf = v.transpose(0, 2, 1, 3).reshape(2 * KV, Sk, 64)
    ref = flash_attention_ref(qf, kf, vf, causal=True)
    ref = ref.reshape(2, H, Sq, 64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_dtypes(dtype, atol):
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), 1, 128, 128, 4, 2, 128, dtype)
    out = flash_attention_op(q, k, v, causal=True)
    ref = multi_head_attention(
        q, k, v,
        jnp.broadcast_to(jnp.arange(128)[None], (1, 128)),
        jnp.broadcast_to(jnp.arange(128)[None], (1, 128)),
        causal=True, window=None, softcap=None, force_blockwise=False)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=atol, rtol=atol)


@pytest.mark.parametrize("window", [None, 32, 100])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_flash_window_softcap(window, softcap):
    q, k, v = _mk_qkv(jax.random.PRNGKey(2), 2, 160, 160, 4, 4, 64, jnp.float32)
    out = flash_attention_op(q, k, v, causal=True, window=window,
                             softcap=softcap, block_q=64, block_k=64)
    pos = jnp.broadcast_to(jnp.arange(160)[None], (2, 160))
    ref = multi_head_attention(q, k, v, pos, pos, causal=True, window=window,
                               softcap=softcap, force_blockwise=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_head_dim_padding():
    """head_dim not a lane multiple (e.g. 80 for zamba2/hubert) is padded."""
    q, k, v = _mk_qkv(jax.random.PRNGKey(3), 1, 128, 128, 4, 2, 80, jnp.float32)
    out = flash_attention_op(q, k, v, causal=True)
    pos = jnp.broadcast_to(jnp.arange(128)[None], (1, 128))
    ref = multi_head_attention(q, k, v, pos, pos, causal=True, window=None,
                               softcap=None, force_blockwise=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (128, 64), (256, 64)])
@pytest.mark.parametrize("H,P,N", [(2, 16, 8), (3, 32, 16)])
def test_ssd_shapes(S, chunk, H, P, N):
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 6)
    b = 2
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, N))
    C = jax.random.normal(ks[4], (b, S, N))
    D = jax.random.normal(ks[5], (H,))
    out = ssd_op(x, dt, A, B, C, D, chunk=chunk)
    ref = ssd_ref(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_ssd_chunk_invariance():
    """The recurrence must make the result independent of chunk size."""
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 6)
    b, S, H, P, N = 1, 128, 2, 16, 8
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, N))
    C = jax.random.normal(ks[4], (b, S, N))
    D = jax.random.normal(ks[5], (H,))
    outs = [ssd_op(x, dt, A, B, C, D, chunk=c) for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-4, rtol=1e-4)


def test_ssd_bf16():
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 6)
    b, S, H, P, N = 1, 64, 2, 16, 8
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))).astype(jnp.bfloat16)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, N), jnp.bfloat16)
    C = jax.random.normal(ks[4], (b, S, N), jnp.bfloat16)
    D = jax.random.normal(ks[5], (H,))
    out = ssd_op(x, dt, A, B, C, D, chunk=32)
    ref = ssd_ref(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                  B.astype(jnp.float32), C.astype(jnp.float32), D, chunk=32)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=0.15, rtol=0.1)
