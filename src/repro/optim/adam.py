"""Pure-JAX optimizers: Adam(W) and SGD+momentum, with grad clipping and
cosine/linear-warmup schedules. No external deps (optax is not available
offline)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(z, params), jax.tree.map(z, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamState, params):
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        m = jax.tree.map(lambda mu, g: self.b1 * mu + (1 - self.b1)
                         * g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(lambda nu, g: self.b2 * nu + (1 - self.b2)
                         * jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t
        lr = self._lr(step)

        def upd(p, mu, nu):
            u = (mu / bc1) / (jnp.sqrt(nu / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamState(step, m, v)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Callable | float = 1e-2
    momentum: float = 0.9
    clip_norm: Optional[float] = None

    def init(self, params) -> SGDState:
        return SGDState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                     params))

    def update(self, grads, state: SGDState, params):
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        mom = jax.tree.map(lambda b, g: self.momentum * b + g.astype(jnp.float32),
                           state.momentum, grads)
        new_params = jax.tree.map(
            lambda p, b: (p.astype(jnp.float32) - lr * b).astype(p.dtype),
            params, mom)
        return new_params, SGDState(step, mom)


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads)


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return sched
