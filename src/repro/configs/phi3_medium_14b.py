"""Phi-3-medium-14B [arXiv:2404.14219]: dense, RoPE, SwiGLU, GQA kv=10."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    source="arXiv:2404.14219",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    block_pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=10000.0,
    tie_embeddings=False,
    sl_cut=(2, 38),
)
