"""Quickstart: optimize a parallel-SL workflow and train with it.

1. Build a problem instance from the paper's testbed profile (Scenario 2,
   ResNet101 measurements).
2. Solve it three ways (baseline / balanced-greedy / ADMM+Alg.2).
3. Execute the best schedule in the real JAX SL runtime on a reduced
   transformer and watch the loss drop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs import get_config
from repro.core import solve_admm, solve_balanced_greedy, solve_baseline
from repro.data.synthetic import SyntheticLM
from repro.profiling.scenarios import cnn_instance, transformer_instance
from repro.sl.runtime import ParallelSLTrainer
from repro.sl.simulator import gantt

# ---- 1. a scheduling problem from testbed measurements --------------------
inst = cnn_instance("resnet101", J=12, I=3, scenario=2, seed=0)
print(f"instance: J={inst.J} clients, I={inst.I} helpers, horizon T={inst.T}")

# ---- 2. three solution methods --------------------------------------------
base = solve_baseline(inst, seed=0)
greedy = solve_balanced_greedy(inst)
admm = solve_admm(inst, mode="fast", tau_max=8)
print(f"baseline (random+FCFS) makespan: {base.makespan}")
print(f"balanced-greedy        makespan: {greedy.makespan}")
print(f"ADMM + Algorithm 2     makespan: {admm.makespan} "
      f"({admm.iterations} iters, converged={admm.converged})")
print("\nhelper occupancy (f=fwd-prop, b=bwd-prop):")
print(gantt(inst, admm.schedule, width=72))

# ---- 3. run REAL split learning under the optimized schedule ---------------
cfg = get_config("gemma2-2b").reduced(num_layers=2, d_model=128, vocab=256)
sl_inst = transformer_instance(cfg, J=4, I=2, scenario=2, seed=0,
                               slot_s=0.05, batch=4, seq=64)
sched = solve_admm(sl_inst, mode="fast", tau_max=5).schedule
trainer = ParallelSLTrainer(cfg, sl_inst, sched, lr=3e-3)
gen = SyntheticLM(cfg.vocab_size, 64, 4, seed=0)
batches = [next(gen.batches(1)) for _ in range(4)]
print(f"\nparallel SL on {cfg.arch_id} (batch makespan = "
      f"{sched.makespan(sl_inst)} slots):")
for _ in range(5):
    st = trainer.run_round(batches, local_steps=2)
    print(f"  round {st.round_idx}: mean loss {st.mean_loss:.4f}  "
          f"(simulated {st.simulated_time_slots} slots, "
          f"{st.cut_traffic_bytes / 1e6:.1f} MB crossed the cuts)")
