"""Mamba2 block — SSD (state-space duality) [arXiv:2405.21060].

Training path uses the chunked SSD algorithm (quadratic within chunks,
linear recurrence across chunks); decode path is the O(1) state update.
The chunked scan is also the pure-jnp oracle for the Pallas SSD kernel.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from .norms import rmsnorm


def segsum(x):
    """Stable 'segment sum': out[..., i, j] = sum_{k=j+1..i} x[..., k] for
    j < i (lower-triangular), -inf above diagonal. x: [..., Q]."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                return_state: bool = False):
    """Chunked SSD scan (pure jnp reference).

    x : [b, S, H, P]   per-head inputs
    dt: [b, S, H]      softplus-ed step sizes (>0)
    A : [H]            negative decay rates (A < 0 enforced by caller)
    B : [b, S, N]      input projection (single group)
    C : [b, S, N]      output projection
    D : [H]            skip connection
    Returns y: [b, S, H, P] (+ final ssm state [b, H, P, N] if requested).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, f"seq {S} not divisible by chunk {chunk}"
    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                       # [b,nc,Q,H] (<0)
    dA_cum = jnp.cumsum(dA, axis=2)                         # within-chunk
    # 1) intra-chunk (quadratic) term
    L = jnp.exp(segsum(dA.transpose(0, 1, 3, 2)))           # [b,nc,H,Q,Q]
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)          # [b,nc,Q,Q]
    M = scores[:, :, None] * L                              # [b,nc,H,Q,Q]
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtc, xc)
    # 2) chunk states: state_c = sum_k exp(dA_cum[end]-dA_cum[k]) dt_k B_k x_k
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # [b,nc,Q,H]
    states = jnp.einsum("bckn,bckh,bckhp->bchpn",
                        Bc, dtc * decay_to_end, xc)          # [b,nc,H,P,N]
    # 3) inter-chunk recurrence over nc
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # [b,nc,H]

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state BEFORE this chunk

    s0 = (jnp.zeros((b, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [b,nc,H,P,N]
    # 4) inter-chunk output: y_off = C_k . (decay_in * prev_state)
    decay_in = jnp.exp(dA_cum)                               # [b,nc,Q,H]
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                       Cc, decay_in, prev_states.astype(Cc.dtype))
    y = (y_diag + y_off).reshape(b, S, H, P) + x * D[None, None, :, None]
    if return_state:
        return y.astype(x.dtype), final
    return y.astype(x.dtype)


def ssd_decode_step(state, x, dt, A, B, C, D):
    """O(1) recurrent update for one token.

    state: [b, H, P, N]; x: [b, H, P]; dt: [b, H]; B, C: [b, N].
    Returns (y [b, H, P], new_state).
    """
    dA = jnp.exp(dt * A[None, :])                            # [b, H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x, B)
    new_state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C) + x * D[None, :, None]
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------
# Full Mamba2 mixer (projections + conv + SSD + gated norm)
# --------------------------------------------------------------------------
def _causal_conv(u, w, *, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. u: [B, S, Cd], w: [K, Cd]."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i : i + u.shape[1]] * w[i][None, None, :] for i in range(K))
    new_state = full[:, -(K - 1):] if K > 1 else jnp.zeros_like(pad)
    return out, new_state


def mamba2_forward(params, x, cfg: ModelConfig, *,
                   state: Optional[Tuple] = None, return_state: bool = False):
    """x: [B, S, d] -> [B, S, d]. ``state``=(conv_state, ssm_state) for decode."""
    s: SSMConfig = cfg.ssm
    B_, S, d = x.shape
    d_in = s.d_inner(d)
    H = s.num_ssm_heads(d)
    P = s.ssm_head_dim
    N = s.state_size

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [d_in, d_in + (d_in + 2 * N)], axis=-1)
    conv_state = None if state is None else state[0]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], state=conv_state)
    xbc = jax.nn.silu(xbc + params["conv_b"][None, None, :])
    xs, Bp, Cp = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])   # [B,S,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # [H] < 0
    xh = xs.reshape(B_, S, H, P)

    if state is None:
        chunk = min(s.chunk_size, S)
        while S % chunk:
            chunk -= 1
        y = ssd_chunked(xh, dt, A, Bp, Cp, params["D"], chunk=chunk)
        new_ssm = None
    else:
        y, new_ssm = ssd_decode_step(state[1], xh[:, 0], dt[:, 0], A,
                                     Bp[:, 0], Cp[:, 0], params["D"])
        y = y[:, None]
    y = y.reshape(B_, S, d_in)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if return_state or state is not None:
        return out, (new_conv, new_ssm)
    return out


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.num_ssm_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.state_size
    conv = jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype)
    ssm = jnp.zeros((batch, H, s.ssm_head_dim, s.state_size), jnp.float32)
    return conv, ssm
