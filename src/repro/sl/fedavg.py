"""FedAvg aggregation (McMahan et al.) over part trees."""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fedavg(trees: Sequence, weights: Optional[Sequence[float]] = None):
    """Weighted average of identical pytrees."""
    n = len(trees)
    if weights is None:
        w = [1.0 / n] * n
    else:
        tot = float(sum(weights))
        w = [float(x) / tot for x in weights]

    def avg(*leaves):
        acc = leaves[0].astype(jnp.float32) * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            acc = acc + leaf.astype(jnp.float32) * wi
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *trees)
