"""Gemma-2-2B [arXiv:2408.00118]: local+global alternating attention,
attention & final-logit softcapping, pre+post block norms, GeGLU."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-2b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    block_pattern=("local", "attn"),
    mlp_kind="geglu",
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    use_post_norm=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    sl_cut=(2, 24),
)
