"""Analytic per-layer cost model for every supported architecture.

Produces, for any (ModelConfig, cut layers, batch, seq, device, link):
  * FLOPs of part-1 / part-2 / part-3 (fwd; bwd = 2x fwd),
  * bytes crossing each cut (activations fwd, gradients bwd),
  * helper-side memory demand d_j (part-2 params + optimizer + activations),
and quantizes them into the paper's integer slot delays
``r, p, l, l', p', r'`` (Sec. III, Fig. 2).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from .devices import Device

BYTES_PER_ACT = 2  # bf16 activations on the wire and in compute


# --------------------------------------------------------------------------
# Parameter counts
# --------------------------------------------------------------------------
def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    from repro.models.transformer import Spec, model_plan
    import jax

    total = 0
    expert_extra = 0
    plan = model_plan(cfg)
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            plan, is_leaf=lambda x: isinstance(x, Spec))[0]:
        size = int(np.prod(leaf.shape))
        total += size
        keys = [getattr(p, "key", "") for p in path]
        if "expert" in (leaf.axes or ()) and "wi" in keys or (
                "expert" in (leaf.axes or ()) and "wo" in keys):
            expert_extra += size
    if active_only and cfg.moe is not None:
        frac = 1.0 - cfg.moe.experts_per_token / cfg.moe.num_experts
        total -= int(expert_extra * frac)
    return total


# --------------------------------------------------------------------------
# Per-layer forward FLOPs
# --------------------------------------------------------------------------
def _attn_flops(cfg: ModelConfig, B: int, S: int, window: Optional[int]) -> float:
    d, H, KV, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    proj = 2.0 * B * S * d * (H * D + 2 * KV * D) + 2.0 * B * S * H * D * d
    Sk = min(S, window) if window else S
    causal_factor = 0.5 if (cfg.causal and not window) else 1.0
    attn = 2.0 * 2.0 * B * S * Sk * H * D * causal_factor
    return proj + attn


def _mla_flops(cfg: ModelConfig, B: int, S: int) -> float:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    proj = 2.0 * B * S * (
        d * m.q_lora_rank + m.q_lora_rank * H * dqk
        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
        + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
        + H * m.v_head_dim * d)
    attn = 2.0 * B * S * S * H * (dqk + m.v_head_dim) * 0.5
    return proj + attn


def _mlp_flops(cfg: ModelConfig, B: int, S: int, kind: str) -> float:
    d = cfg.d_model
    if kind == "moe":
        mo = cfg.moe
        router = 2.0 * B * S * d * mo.num_experts
        per_tok = 2.0 * d * mo.expert_d_ff * 3 * mo.experts_per_token
        shared = 2.0 * d * mo.expert_d_ff * mo.num_shared_experts * 3
        return router + B * S * (per_tok + shared)
    mult = 3 if kind in ("swiglu", "geglu") else 2
    return 2.0 * B * S * d * cfg.d_ff * mult


def _mamba_flops(cfg: ModelConfig, B: int, S: int) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    H, P, N = s.num_ssm_heads(d), s.ssm_head_dim, s.state_size
    conv_dim = d_in + 2 * N
    proj = 2.0 * B * S * d * (2 * d_in + 2 * N + H) + 2.0 * B * S * d_in * d
    conv = 2.0 * B * S * conv_dim * s.conv_kernel
    Q = s.chunk_size
    ssd = B * S * (2.0 * Q * N + 2.0 * Q * H * P + 4.0 * H * P * N)
    return proj + conv + ssd


def layer_fwd_flops(cfg: ModelConfig, idx: int, B: int, S: int) -> float:
    kind = cfg.layer_kinds[idx]
    mlp_kind = cfg.mlp_kind_for_layer(idx)
    if kind == "mamba":
        return _mamba_flops(cfg, B, S)
    if kind == "mla":
        mix = _mla_flops(cfg, B, S)
    else:
        window = cfg.sliding_window if kind == "local" else None
        mix = _attn_flops(cfg, B, S, window)
    return mix + _mlp_flops(cfg, B, S, mlp_kind)


def embed_flops(cfg: ModelConfig, B: int, S: int) -> float:
    return 2.0 * B * S * cfg.d_model * cfg.vocab_size  # unembed matmul


def model_fwd_flops(cfg: ModelConfig, B: int, S: int) -> float:
    return sum(layer_fwd_flops(cfg, i, B, S)
               for i in range(cfg.num_layers)) + embed_flops(cfg, B, S)


def model_flops_6nd(cfg: ModelConfig, B: int, S: int) -> float:
    """MODEL_FLOPS = 6 N D (N = active params, D = tokens) for roofline."""
    return 6.0 * count_params(cfg, active_only=True) * B * S


# --------------------------------------------------------------------------
# Split costs (part-1 | part-2 | part-3)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SplitCosts:
    fwd_flops: Tuple[float, float, float]  # part-1, part-2, part-3
    cut1_bytes: float   # activations/gradients crossing sigma_1
    cut2_bytes: float   # activations/gradients crossing sigma_2
    part2_param_bytes: float
    part2_act_bytes: float


def layer_params(cfg: ModelConfig, idx: int) -> int:
    """Approximate per-layer parameter count (for memory demand d_j)."""
    kind = cfg.layer_kinds[idx]
    mlp_kind = cfg.mlp_kind_for_layer(idx)
    d = cfg.d_model
    if kind == "mamba":
        s = cfg.ssm
        d_in = s.d_inner(d)
        return d * (2 * d_in + 2 * s.state_size + s.num_ssm_heads(d)) + d_in * d
    if kind == "mla":
        m = cfg.mla
        H = cfg.num_heads
        mix = (d * m.q_lora_rank
               + m.q_lora_rank * H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
               + d * (m.kv_lora_rank + m.qk_rope_head_dim)
               + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
               + H * m.v_head_dim * d)
    else:
        H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        mix = d * (H * D + 2 * KV * D) + H * D * d
    if mlp_kind == "moe":
        mo = cfg.moe
        mlp = d * mo.num_experts + mo.num_experts * d * mo.expert_d_ff * 3 \
            + mo.num_shared_experts * d * mo.expert_d_ff * 3
    else:
        mult = 3 if mlp_kind in ("swiglu", "geglu") else 2
        mlp = d * cfg.d_ff * mult
    return int(mix + mlp)


def split_costs(cfg: ModelConfig, B: int, S: int,
                cut: Optional[Tuple[int, int]] = None) -> SplitCosts:
    s1, s2 = cut if cut is not None else cfg.sl_cuts_resolved
    assert 0 <= s1 <= s2 <= cfg.num_layers
    per_layer = [layer_fwd_flops(cfg, i, B, S) for i in range(cfg.num_layers)]
    f1 = sum(per_layer[:s1])
    f2 = sum(per_layer[s1:s2])
    f3 = sum(per_layer[s2:]) + embed_flops(cfg, B, S)
    cut_bytes = float(B * S * cfg.d_model * BYTES_PER_ACT)
    p2_params = sum(layer_params(cfg, i) for i in range(s1, s2))
    # stored activations in part-2 (per layer ~4x the residual stream, bf16)
    p2_acts = float((s2 - s1) * B * S * cfg.d_model * 4 * BYTES_PER_ACT)
    return SplitCosts(
        fwd_flops=(f1, f2, f3),
        cut1_bytes=cut_bytes,
        cut2_bytes=cut_bytes,
        part2_param_bytes=float(p2_params) * 4,  # fp32 master copy
        part2_act_bytes=p2_acts,
    )


# --------------------------------------------------------------------------
# Delay synthesis (the paper's r, p, l, l', p', r')
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EdgeDelays:
    r: int
    p: int
    l: int
    lp: int
    pp: int
    rp: int


def edge_delays(costs: SplitCosts, client: Device, helper: Device,
                up_Bps: float, down_Bps: float, slot_s: float,
                *, bwd_mult: float = 2.0) -> EdgeDelays:
    f1, f2, f3 = costs.fwd_flops

    def slots(t, minimum=0):
        return max(int(np.ceil(t / slot_s)), minimum)

    r = slots(f1 / client.flops + costs.cut1_bytes / up_Bps)
    p = slots(f2 / helper.flops, 1)
    l = slots(costs.cut2_bytes / down_Bps + f3 / client.flops)
    lp = slots(bwd_mult * f3 / client.flops + costs.cut2_bytes / up_Bps)
    pp = slots(bwd_mult * f2 / helper.flops, 1)
    rp = slots(costs.cut1_bytes / down_Bps + bwd_mult * f1 / client.flops)
    return EdgeDelays(r=r, p=p, l=l, lp=lp, pp=pp, rp=rp)


def helper_memory_demand_gb(costs: SplitCosts) -> float:
    """d_j: part-2 master params + Adam m,v + stored activations (GB)."""
    opt = costs.part2_param_bytes * 3  # fp32 params + m + v
    return (opt + costs.part2_act_bytes) / 1e9
