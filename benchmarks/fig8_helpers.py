"""Fig. 8 reproduction: batch makespan vs number of helpers (J=100 clients,
Scenario 1, balanced-greedy — Observation 4)."""

from __future__ import annotations

import numpy as np

from repro.core import solve_balanced_greedy
from repro.profiling.scenarios import cnn_instance

HELPERS = [1, 2, 3, 5, 10, 15, 20]


def run(model: str = "resnet101", J: int = 100, seeds=(0, 1, 2)):
    rows = []
    prev = None
    for I in HELPERS:
        mks = []
        for seed in seeds:
            inst = cnn_instance(model, J=J, I=I, scenario=1, seed=seed)
            mks.append(solve_balanced_greedy(inst).makespan)
        mk = float(np.mean(mks))
        gain = (100.0 * (prev - mk) / prev) if prev else 0.0
        rows.append({"model": model, "J": J, "I": I,
                     "makespan": round(mk, 1),
                     "gain_vs_prev_pct": round(gain, 1)})
        prev = mk
    return rows


def main():
    rows = run()
    print("  I  makespan  gain_vs_prev%")
    for r in rows:
        print(f"{r['I']:3d} {r['makespan']:9.1f} {r['gain_vs_prev_pct']:13.1f}")
    return rows


if __name__ == "__main__":
    main()
