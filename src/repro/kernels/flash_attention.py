"""Flash attention Pallas TPU kernel (causal / sliding-window / softcap).

TPU-native tiling: queries are processed in [block_q, D] VMEM tiles, keys and
values stream through [block_k, D] tiles along the minor (sequential) grid
axis; the online-softmax state (m, l, acc) lives in VMEM scratch that
persists across the k-block sweep. GQA is handled without materializing
repeated K/V: the K/V index_map divides the (batch*head) grid coordinate by
the query-group size.

Validated in interpret mode against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, num_kb: int, scale: float,
                  causal: bool, window: Optional[int],
                  softcap: Optional[float], seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # [block_q, D]
    k = k_ref[0].astype(jnp.float32)              # [block_k, D]
    v = v_ref[0].astype(jnp.float32)              # [block_k, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_k                          # padding
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: [BH, Sq, D]; k, v: [BKV, Sk, D] with BH = BKV * rep.

    Sequence lengths are padded to block multiples internally; D should be a
    multiple of 128 for MXU alignment on real TPUs (ops.py pads).
    """
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    rep = BH // BKV
    scale = 1.0 / (D ** 0.5)

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    num_qb = q.shape[1] // block_q
    num_kb = k.shape[1] // block_k

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, num_kb=num_kb,
        scale=scale, causal=causal, window=window, softcap=softcap, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(BH, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki, rep=rep: (b // rep, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki, rep=rep: (b // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
