"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

Chunked scan: each grid step processes one [Q, P] chunk of one (batch, head)
pair — quadratic attention-like math within the chunk in VMEM, with the
[N, P] recurrent state carried across chunks in VMEM scratch (the chunk axis
is the minor, sequential grid dimension). This is the TPU-native adaptation
of the Mamba2 GPU kernel: no warp-level shuffles, just MXU matmuls over
VMEM tiles and a scratch-carried recurrence.

Validated in interpret mode against ``ref.ssd_ref`` (the pure-jnp chunked
scan used by the model).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # [Q]
    A = A_ref[0].astype(jnp.float32)               # scalar
    Bm = B_ref[0].astype(jnp.float32)              # [Q, N]
    Cm = C_ref[0].astype(jnp.float32)              # [Q, N]
    Dv = D_ref[0].astype(jnp.float32)              # scalar

    dA = dt * A                                     # [Q] (negative)
    cum = jnp.cumsum(dA)
    # intra-chunk lower-triangular decay matrix
    Lmat = jnp.exp(cum[:, None] - cum[None, :])
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    k_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(q_idx >= k_idx, Lmat, 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    M = scores * Lmat * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q,P]

    # inter-chunk contribution from the carried state [N, P]
    decay_in = jnp.exp(cum)                                           # [Q]
    y += (jax.lax.dot_general(Cm, state_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
          * decay_in[:, None])

    # state update: state = decay_chunk * state + (B * dt * decay_to_end)^T x
    decay_to_end = jnp.exp(cum[-1] - cum)                             # [Q]
    weighted_B = Bm * (dt * decay_to_end)[:, None]                    # [Q, N]
    state_ref[...] = (state_ref[...] * jnp.exp(cum[-1])
                      + jax.lax.dot_general(
                          weighted_B, x, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    y_ref[0, :, 0, :] = (y + Dv * x).astype(y_ref.dtype)


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 64, interpret: bool = True):
    """x: [b, S, H, P]; dt: [b, S, H]; A, D: [H]; B, C: [b, S, N].
    Returns y: [b, S, H, P]. S must be divisible by ``chunk``."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, f"S={S} not divisible by chunk={chunk}"
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P),
                         lambda bh, c, H=H: (bh // H, c, bh % H, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda bh, c, H=H: (bh // H, c, bh % H)),
            pl.BlockSpec((1,), lambda bh, c, H=H: (bh % H,)),
            pl.BlockSpec((1, chunk, N), lambda bh, c, H=H: (bh // H, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c, H=H: (bh // H, c, 0)),
            pl.BlockSpec((1,), lambda bh, c, H=H: (bh % H,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P),
                               lambda bh, c, H=H: (bh // H, c, bh % H, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D)
    return y
