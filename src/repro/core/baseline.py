"""Baseline scheme of Sec. VII: random feasible assignment + FCFS schedule.

"A naive real-time implementation of parallel SL without proactive decisions
on assignments or scheduling."
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .balanced_greedy import schedule_fcfs
from .instance import Instance
from .schedule import Schedule, check_feasible


@dataclasses.dataclass
class BaselineResult:
    schedule: Schedule
    makespan: int
    runtime_s: float


def assign_random(inst: Instance, *, seed: int = 0, max_tries: int = 200) -> np.ndarray:
    """Random assignment subject to memory constraints (rejection sampling
    with per-client fallback to feasible helpers)."""
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        free_mem = inst.m.astype(np.float64).copy()
        assign = np.full(inst.J, -1, dtype=np.int64)
        perm = rng.permutation(inst.J)
        ok = True
        for j in perm:
            cands = [i for i in range(inst.I)
                     if inst.is_edge(i, int(j)) and free_mem[i] >= inst.d[int(j)]]
            if not cands:
                ok = False
                break
            i = int(rng.choice(cands))
            assign[int(j)] = i
            free_mem[i] -= inst.d[int(j)]
        if ok:
            return assign
    raise ValueError("could not sample a feasible random assignment")


def solve_baseline(inst: Instance, *, seed: int = 0,
                   horizon: Optional[int] = None) -> BaselineResult:
    t0 = time.perf_counter()
    T = int(horizon if horizon is not None else inst.T)
    assign = assign_random(inst, seed=seed)
    sched = schedule_fcfs(inst, assign, horizon=T)
    check_feasible(inst, sched, horizon=T)
    return BaselineResult(schedule=sched, makespan=sched.makespan(inst),
                          runtime_s=time.perf_counter() - t0)
