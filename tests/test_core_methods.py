"""System-level tests of the paper's solution methods."""

import numpy as np
import pytest

from repro.core import (check_feasible, full_schedule_for_assignment,
                        lower_bound, random_instance, solve_admm,
                        solve_balanced_greedy, solve_baseline, solve_exact,
                        solve_local_search, solve_strategy, queuing_delay)
from repro.core.balanced_greedy import assign_balanced


@pytest.mark.parametrize("seed", range(6))
def test_all_methods_feasible_and_bounded(seed):
    inst = random_instance(10, 3, seed=seed)
    lb = lower_bound(inst)
    for name, res in [
        ("greedy", solve_balanced_greedy(inst)),
        ("baseline", solve_baseline(inst, seed=seed)),
        ("admm", solve_admm(inst, mode="fast", tau_max=6)),
        ("ls", solve_local_search(inst, time_budget_s=3)),
    ]:
        check_feasible(inst, res.schedule)
        assert res.makespan >= lb, f"{name}: makespan {res.makespan} < LB {lb}"
        assert res.makespan <= inst.T, f"{name}: makespan beyond horizon"


def test_admm_near_optimal_tiny():
    inst = random_instance(4, 2, seed=3, p_range=(1, 4), pp_range=(1, 5),
                           r_range=(1, 3), l_range=(1, 2), lp_range=(1, 2),
                           rp_range=(1, 3))
    ex = solve_exact(inst, time_limit=120)
    assert ex.status == "optimal"
    check_feasible(inst, ex.schedule)
    a = solve_admm(inst, mode="fast")
    assert a.makespan >= ex.schedule.makespan(inst)
    # paper Table II: sub-15% gap in the worst tested case; allow slack here
    assert a.makespan <= 1.5 * ex.schedule.makespan(inst)


def test_exact_milp_feasible_and_optimal_objective():
    inst = random_instance(4, 2, seed=7, p_range=(1, 4), pp_range=(1, 5),
                           r_range=(1, 3), l_range=(1, 2), lp_range=(1, 2),
                           rp_range=(1, 3))
    ex = solve_exact(inst, time_limit=120)
    assert ex.status == "optimal"
    check_feasible(inst, ex.schedule)
    assert ex.schedule.makespan(inst) == pytest.approx(ex.objective)
    assert ex.objective >= lower_bound(inst)


def test_local_search_improves_or_ties_greedy():
    inst = random_instance(12, 4, seed=11, heterogeneity=2.0)
    g = solve_balanced_greedy(inst)
    ls = solve_local_search(inst, init=g.schedule.assign.copy(), time_budget_s=5)
    assert ls.makespan <= g.makespan


def test_strategy_picks_and_returns_feasible():
    small = random_instance(8, 3, seed=0, heterogeneity=2.0)
    res = solve_strategy(small)
    check_feasible(small, res.schedule)
    large = random_instance(70, 8, seed=0, heterogeneity=0.2)
    res2 = solve_strategy(large, large_j=60)
    check_feasible(large, res2.schedule)
    assert res2.method == "balanced-greedy"


def test_preemption_cost_extension():
    inst = random_instance(8, 3, seed=2)
    inst_mu = random_instance(8, 3, seed=2)
    object.__setattr__(inst_mu, "mu", np.full(inst.I, 2.0))
    a = solve_admm(inst, mode="fast", tau_max=5)
    plain = a.schedule.makespan(inst)
    with_cost = a.schedule.makespan_with_preemption_cost(inst_mu)
    assert with_cost >= plain  # switching can only add delay
    # zero switching cost reduces to the plain makespan
    object.__setattr__(inst_mu, "mu", np.zeros(inst.I))
    assert a.schedule.makespan_with_preemption_cost(inst_mu) == plain


def test_queuing_delay_nonnegative():
    inst = random_instance(10, 2, seed=4)
    res = solve_balanced_greedy(inst)
    for j in range(inst.J):
        assert queuing_delay(inst, res.schedule, j) >= 0


def test_slot_length_rescaling_tradeoff():
    """Observation 2: coarser slots -> shorter horizon (fewer variables)."""
    inst = random_instance(10, 3, seed=6, p_range=(4, 40), pp_range=(4, 56),
                           r_range=(4, 32), l_range=(4, 24), lp_range=(4, 24),
                           rp_range=(4, 32))
    coarse = inst.scaled(4.0)
    assert coarse.T < inst.T
    fine_res = solve_admm(inst, mode="fast", tau_max=5)
    coarse_res = solve_admm(coarse, mode="fast", tau_max=5)
    # compare in original time units: coarse slots are 4x longer
    assert coarse_res.makespan * 4 >= fine_res.makespan * 0.8


def test_memory_constraints_respected():
    inst = random_instance(12, 3, seed=9, mem_tight=1.2)
    assign = assign_balanced(inst)
    sched = full_schedule_for_assignment(inst, assign)
    check_feasible(inst, sched)
    for i in range(inst.I):
        load = sum(inst.d[j] for j in range(inst.J) if assign[j] == i)
        assert load <= inst.m[i] + 1e-9
