"""Gemma-3-27B [hf:google/gemma-3-1b-pt family]: 5 local : 1 global attention,
sliding window 1024, QK-norm, 128k context."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    block_pattern=("local",) * 5 + ("attn",),
    mlp_kind="geglu",
    sliding_window=1024,
    use_qk_norm=True,
    use_post_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    sl_cut=(2, 60),
)
