"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel body
runs in Python, validating the exact TPU program); on a real TPU pass
``interpret=False``. ``flash_attention_op`` additionally pads head_dim to a
multiple of 128 for MXU lane alignment.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .ssd_scan import ssd_scan

ON_TPU = any(d.platform == "tpu" for d in jax.devices())


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       block_q: int = 128, block_k: int = 128,
                       interpret: Optional[bool] = None):
    """q: [B, Sq, H, D]; k, v: [B, Sk, KV, D]. Returns [B, Sq, H, D]."""
    interpret = (not ON_TPU) if interpret is None else interpret
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    padD = (-D) % 128
    scale_fix = ((D + padD) / D) ** 0.5  # kernel scales by padded dim
    if padD:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, padD))) * scale_fix
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, padD)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, padD)))
    Dp = D + padD
    # fold heads into batch; queries grouped so GQA maps to index division
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dp)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dp)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dp)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          softcap=softcap, block_q=block_q, block_k=block_k,
                          interpret=interpret)
    out = out.reshape(B, H, Sq, Dp).transpose(0, 2, 1, 3)
    return out[..., :D]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_op(x, dt, A, B, C, D, *, chunk: int = 64,
           interpret: Optional[bool] = None):
    """Chunked SSD scan; see ssd_scan.py for shapes."""
    interpret = (not ON_TPU) if interpret is None else interpret
    return ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=interpret)
