"""Production training launcher (single-host CPU execution path).

On real hardware this runs under the production mesh; on this container it
executes reduced configs on the CPU device mesh (1x1). The same step
function, sharding rules, and data pipeline are used in both cases —
``--dry-run`` switches to lowering-only against the 16x16 / 2x16x16 meshes.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b-smoke \
      --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import SyntheticLM, make_batch
from repro.models.transformer import Runtime, init_params, loss_fn
from repro.optim.adam import Adam, warmup_cosine
from repro.checkpoint import ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--scan", action="store_true", help="scan layers")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    rt = Runtime(scan_layers=args.scan)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.arch_id}: {n_params/1e6:.2f}M params")

    opt = Adam(lr=warmup_cosine(args.lr, warmup=10, total=args.steps))
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: loss_fn(cfg, pp, b, rt), has_aux=True)(p)
        p2, s2 = opt.update(grads, s, p)
        return p2, s2, loss

    if cfg.frontend is None:
        gen = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
        batches = gen.batches(args.steps)
        get_batch = lambda _: {k: jnp.asarray(v)
                               for k, v in next(batches).items()}
    else:
        get_batch = lambda i: {k: jnp.asarray(v) for k, v in
                               make_batch(cfg, args.batch, args.seq,
                                          seed=args.seed + i).items()}

    t0 = time.perf_counter()
    for i in range(args.steps):
        params, state, loss = step(params, state, get_batch(i))
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"[train] step {i:5d} loss {float(loss):8.4f} "
                  f"({dt:.1f}s elapsed)")
    if args.ckpt:
        ckpt.save(args.ckpt, params, step=args.steps)
        print(f"[train] saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
