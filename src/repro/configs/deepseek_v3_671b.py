"""DeepSeek-V3-671B [arXiv:2412.19437]: MLA, 1 shared + 256 routed top-8 MoE,
first 3 layers dense, multi-token prediction (MTP) depth 1."""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,   # MLA: all heads share the compressed latent KV
    d_ff=18432,         # dense-layer FFN width (first_k_dense layers)
    vocab_size=129280,
    block_pattern=("mla",),
    mlp_kind="moe",
    first_k_dense=3,
    moe=MoEConfig(num_experts=256, experts_per_token=8, expert_d_ff=2048,
                  num_shared_experts=1, router_aux_coef=0.001),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    rope_theta=10000.0,
    tie_embeddings=False,
    mtp_depth=1,
    sl_cut=(2, 59),
)
