"""Substrate tests: optimizers, checkpointing, data pipeline, cost model."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.synthetic import SyntheticLM, make_batch
from repro.optim.adam import (Adam, SGD, clip_by_global_norm, global_norm,
                              warmup_cosine)


def test_adam_minimizes_quadratic():
    opt = Adam(lr=0.1)
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-3


def test_sgd_momentum_minimizes():
    opt = SGD(lr=0.05, momentum=0.9)
    params = jnp.array([4.0, 4.0])
    state = opt.init(params)
    loss = lambda p: jnp.sum(p ** 2)
    for _ in range(100):
        params, state = opt.update(jax.grad(loss)(params), state, params)
    assert float(loss(params)) < 1e-3


def test_grad_clipping():
    g = {"a": jnp.full((10,), 100.0)}
    clipped = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((10,), 1e-3)}
    same = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(same["a"], small["a"])


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == pytest.approx(1.0)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)
    assert float(sched(jnp.int32(55))) < 1.0


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
        "layers": [np.ones((2,)), np.zeros((3,))],
        "t": (np.array(1), np.array([2.0])),
    }
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, tree, step=42, extra={"note": "hi"})
    loaded, meta = ckpt.load(path)
    assert meta["step"] == 42 and meta["note"] == "hi"
    assert isinstance(loaded["layers"], list)
    assert isinstance(loaded["t"], tuple)
    np.testing.assert_array_equal(loaded["a"]["w"], tree["a"]["w"])
    np.testing.assert_array_equal(loaded["layers"][1], tree["layers"][1])


def test_synthetic_lm_determinism_and_learnability():
    gen1 = SyntheticLM(256, 32, 4, seed=7)
    gen2 = SyntheticLM(256, 32, 4, seed=7)
    b1 = next(gen1.batches(1))
    b2 = next(gen2.batches(1))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # bigram structure: successor entropy must be far below uniform
    toks = np.concatenate([next(gen1.batches(1))["tokens"].ravel()
                           for _ in range(20)])
    # P(next in successor table | cur) should be high
    from repro.data.synthetic import SyntheticLM as S
    succ = gen1._succ
    pairs = np.stack([toks[:-1], toks[1:]])
    hits = np.mean([pairs[1, i] in succ[pairs[0, i]]
                    for i in range(0, pairs.shape[1], 7)])
    assert hits > 0.5


def test_make_batch_modalities():
    from repro.configs import get_config
    for arch, keys in [("gemma2-2b", {"tokens"}),
                       ("paligemma-3b", {"tokens", "patches"}),
                       ("hubert-xlarge", {"frames", "labels"})]:
        cfg = get_config(arch).reduced()
        b = make_batch(cfg, 2, 16)
        assert set(b) == keys
