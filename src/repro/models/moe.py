"""Mixture-of-Experts block.

Two implementations:

* ``dense`` — exact oracle: every expert computed on every token, masked by
  router weights. Used for smoke tests / correctness (small E only).
* ``ep_a2a`` — production expert parallelism, TPU-native: tokens are routed
  with top-k, bucketed per destination device (experts sharded over the
  ``model`` mesh axis), exchanged with ``jax.lax.all_to_all`` inside
  ``shard_map``, processed with ``jax.lax.ragged_dot`` (MegaBlocks-style
  grouped matmul, no [T, E, C] one-hot blowup), and returned. Over-capacity
  entries are dropped (standard capacity-factor semantics).

Router aux loss is the switch-style load-balance loss E * sum_e f_e P_e.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from .mlp import mlp_forward


def router_topk(logits, k: int):
    """logits: [T, E] -> (weights [T, k] normalized, ids [T, k], probs [T, E])."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, ids, probs


def load_balance_loss(probs, ids, num_experts: int):
    """Switch-transformer aux loss: E * sum_e fraction_e * prob_e."""
    T = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(ids.size, 1)
    mean_prob = probs.mean(axis=0)
    return num_experts * jnp.sum(frac * mean_prob)


def _expert_ffn_dense(params, x, e: int):
    """SwiGLU expert e over all tokens. params['wi']: [E, d, 2, ff]."""
    h = jnp.einsum("td,dgf->tgf", x, params["wi"][e])
    h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    return jnp.einsum("tf,fd->td", h, params["wo"][e])


def moe_dense(params, x, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact masked-dense MoE. x: [T, d]."""
    logits = jnp.einsum("td,de->te", x, params["router"])
    weights, ids, probs = router_topk(logits, cfg.experts_per_token)
    aux = load_balance_loss(probs, ids, cfg.num_experts)
    gate = jnp.zeros((x.shape[0], cfg.num_experts), jnp.float32)
    gate = gate.at[jnp.arange(x.shape[0])[:, None], ids].add(weights)
    out = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        out = out + gate[:, e : e + 1].astype(x.dtype) * _expert_ffn_dense(params, x, e)
    return out, aux


def _grouped_ffn(wi, wo, x_sorted, group_sizes):
    """ragged_dot SwiGLU over expert-sorted rows. wi: [E, d, 2, ff]."""
    gate = jax.lax.ragged_dot(x_sorted, wi[:, :, 0, :], group_sizes)
    up = jax.lax.ragged_dot(x_sorted, wi[:, :, 1, :], group_sizes)
    h = (jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)).astype(x_sorted.dtype)
    return jax.lax.ragged_dot(h, wo, group_sizes)


def moe_ep_shard(params, x, cfg: MoEConfig, axis_name: str,
                 pmean_axes: Tuple[str, ...] = ()):
    """Per-shard body of the expert-parallel MoE (runs under shard_map).

    x: [T_loc, d] local tokens. params['wi']: [E_loc, d, 2, ff] — the local
    shard of the expert weights. Experts are sharded over ``axis_name``.
    """
    n_dev = jax.lax.axis_size(axis_name)
    T, d = x.shape
    E = cfg.num_experts
    E_loc = E // n_dev
    k = cfg.experts_per_token

    logits = jnp.einsum("td,de->te", x, params["router"])
    weights, ids, probs = router_topk(logits, k)
    aux = load_balance_loss(probs, ids, E)
    for ax in pmean_axes:
        aux = jax.lax.pmean(aux, ax)

    N = T * k
    flat_ids = ids.reshape(N)                      # expert id per entry
    flat_w = weights.reshape(N)
    dest = flat_ids // E_loc                       # destination device
    local_eid = flat_ids % E_loc                   # expert id on destination
    # position of each entry within its destination bucket
    order = jnp.argsort(dest, stable=True)
    ranks = jnp.zeros((N,), jnp.int32).at[order].set(jnp.arange(N, dtype=jnp.int32))
    dest_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(dest, length=n_dev)).astype(jnp.int32)])[:-1]
    pos = ranks - dest_start[dest]
    C = int(-(-N * cfg.capacity_factor // n_dev))  # per-destination capacity
    valid = pos < C
    # over-capacity entries go to a dump slot C (sliced off) so they cannot
    # clobber valid entries
    pos_w = jnp.where(valid, pos, C)

    # ---- pack send buffers [n_dev, C, ...] and exchange ------------------
    send_x = jnp.zeros((n_dev, C + 1, d), x.dtype).at[dest, pos_w].set(
        x[jnp.arange(N) // k])[:, :C]
    send_eid = jnp.zeros((n_dev, C + 1), jnp.int32).at[dest, pos_w].set(
        local_eid)[:, :C]
    send_valid = jnp.zeros((n_dev, C + 1), jnp.bool_).at[dest, pos_w].set(
        valid)[:, :C]
    recv_x = jax.lax.all_to_all(send_x, axis_name, 0, 0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid[..., None], axis_name, 0, 0)[..., 0]
    recv_valid = jax.lax.all_to_all(send_valid[..., None].astype(jnp.int8),
                                    axis_name, 0, 0)[..., 0]

    # ---- local grouped expert compute ------------------------------------
    M = n_dev * C
    rx = recv_x.reshape(M, d)
    reid = recv_eid.reshape(M)
    rvalid = recv_valid.reshape(M) > 0
    # invalid slots -> expert 0 with zero input (cheap, correct on return)
    reid = jnp.where(rvalid, reid, 0)
    sort_idx = jnp.argsort(reid, stable=True)
    x_sorted = rx[sort_idx]
    group_sizes = jnp.bincount(reid, length=E_loc).astype(jnp.int32)
    y_sorted = _grouped_ffn(params["wi"], params["wo"], x_sorted, group_sizes)
    y_local = jnp.zeros_like(rx).at[sort_idx].set(y_sorted)

    # ---- return path ------------------------------------------------------
    back = jax.lax.all_to_all(y_local.reshape(n_dev, C, d), axis_name, 0, 0)
    pos_g = jnp.where(valid, pos, 0)               # clamped gather index
    gathered = back[dest, pos_g]                   # [N, d]
    contrib = jnp.where(valid[:, None], gathered, 0) * flat_w[:, None].astype(x.dtype)
    out = jnp.zeros_like(x).at[jnp.arange(N) // k].add(contrib)
    return out, aux


def moe_ep_local_shard(params, x, cfg: MoEConfig, axis_name: str,
                       pmean_axes: Tuple[str, ...] = ()):
    """Replicated-token expert parallelism (for decode: few tokens, no a2a).

    Every rank along ``axis_name`` sees the SAME tokens, computes only its
    local experts' contributions via ragged_dot, and psums the output.
    x: [T, d] (identical across the axis). params['wi']: [E_loc, d, 2, ff].
    """
    n_dev = jax.lax.axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    T, d = x.shape
    E = cfg.num_experts
    E_loc = E // n_dev
    k = cfg.experts_per_token

    logits = jnp.einsum("td,de->te", x, params["router"])
    weights, ids, probs = router_topk(logits, k)
    aux = load_balance_loss(probs, ids, E)
    for ax in pmean_axes:
        aux = jax.lax.pmean(aux, ax)

    N = T * k
    flat_ids = ids.reshape(N)
    flat_w = weights.reshape(N)
    local = (flat_ids // E_loc) == me
    # non-local entries go to a dummy group E_loc (zero-weight expert)
    gid = jnp.where(local, flat_ids % E_loc, E_loc)
    sort_idx = jnp.argsort(gid, stable=True)
    x_sorted = x[(jnp.arange(N) // k)[sort_idx]]
    group_sizes = jnp.bincount(gid, length=E_loc + 1).astype(jnp.int32)
    zpad = jnp.zeros((1,) + params["wi"].shape[1:], params["wi"].dtype)
    wi = jnp.concatenate([params["wi"], zpad], axis=0)
    wo = jnp.concatenate(
        [params["wo"], jnp.zeros((1,) + params["wo"].shape[1:],
                                 params["wo"].dtype)], axis=0)
    y_sorted = _grouped_ffn(wi, wo, x_sorted, group_sizes)
    y_entries = jnp.zeros_like(y_sorted).at[sort_idx].set(y_sorted)
    contrib = jnp.where(local[:, None], y_entries, 0) * flat_w[:, None].astype(x.dtype)
    out = jnp.zeros_like(x).at[jnp.arange(N) // k].add(contrib)
    return jax.lax.psum(out, axis_name), aux


def moe_forward(params, x, model_cfg: ModelConfig, *, mode: str = "dense",
                mesh=None, data_axes: Tuple[str, ...] = ("data",),
                model_axis: str = "model"):
    """x: [B, S, d] -> (y, aux_loss). Adds shared experts if configured.

    * ``dense``    — oracle (no mesh needed).
    * ``ep_a2a``   — shard_map: tokens split over (data_axes, model_axis),
                     experts over model_axis, exchanged with all_to_all.
    * ``ep_local`` — shard_map: tokens split over data_axes only (replicated
                     over model_axis), experts local + psum. For decode.
    """
    from jax.sharding import PartitionSpec as P
    from jax import shard_map

    cfg = model_cfg.moe
    B, S, d = x.shape
    if mode == "dense":
        y, aux = moe_dense(params, x.reshape(B * S, d), cfg)
        y = y.reshape(B, S, d)
    elif mode in ("ep_a2a", "ep_local"):
        if mesh is None:
            raise ValueError(f"moe mode {mode} requires a mesh")
        all_axes = tuple(data_axes) + (model_axis,)
        pspec_params = {
            "router": P(),
            "wi": P(model_axis),
            "wo": P(model_axis),
        }
        if cfg.num_shared_experts:
            pspec_params["shared"] = {"wi": P(), "wo": P()}
        ep_params = {k_: params[k_] for k_ in pspec_params}
        if mode == "ep_a2a":
            xspec = P(tuple(data_axes), model_axis, None)
            body = lambda p, xx: moe_ep_shard(
                p, xx.reshape(-1, d), cfg, model_axis, all_axes)
        else:
            xspec = P(tuple(data_axes), None, None)
            body = lambda p, xx: moe_ep_local_shard(
                p, xx.reshape(-1, d), cfg, model_axis, all_axes)

        def wrapped(p, xx):
            bs, ss = xx.shape[:2]
            y_flat, aux_ = body(p, xx)
            return y_flat.reshape(bs, ss, d), aux_

        y, aux = shard_map(
            wrapped, mesh=mesh,
            in_specs=(pspec_params, xspec),
            out_specs=(xspec, P()))(ep_params, x)
    else:
        raise ValueError(f"unknown moe mode {mode}")
    if cfg.num_shared_experts > 0:
        xt = x.reshape(B * S, d)
        y = y + mlp_forward(params["shared"], xt, "swiglu").reshape(B, S, d)
    return y, aux
