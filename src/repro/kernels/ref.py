"""Pure-jnp oracles for every Pallas kernel (single source of truth: the
model-side implementations in ``repro.models``)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models.attention import _dot_attention, attn_mask
from repro.models.ssm import ssd_chunked


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None):
    """q: [BH, Sq, D]; k, v: [BKV, Sk, D]. Oracle for the flash kernel."""
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    rep = BH // BKV
    # reshape into the model-side [B, S, H, D] convention with B = BKV
    qm = q.reshape(BKV, rep, Sq, D).transpose(0, 2, 1, 3)  # [BKV, Sq, rep, D]
    km = k[:, :, None, :]
    vm = v[:, :, None, :]
    q_pos = jnp.broadcast_to(jnp.arange(Sq)[None], (BKV, Sq))
    k_pos = jnp.broadcast_to(jnp.arange(Sk)[None], (BKV, Sk))
    mask = attn_mask(q_pos, k_pos, causal=causal, window=window)
    out = _dot_attention(qm, km, vm, mask, softcap)       # [BKV, Sq, rep, D]
    return out.transpose(0, 2, 1, 3).reshape(BH, Sq, D)


def ssd_ref(x, dt, A, B, C, D, *, chunk: int):
    """Oracle for the SSD kernel: the model-side chunked scan."""
    return ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
