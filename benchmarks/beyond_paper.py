"""Beyond-paper scheduling extensions benchmark:

1. per-client cut-layer co-optimization (the paper's stated future work),
2. multi-batch pipelining vs the paper's batch-by-batch regime,
3. local search with optimal inner scheduling vs the paper's two methods.
"""

from __future__ import annotations

import numpy as np

from repro.core import (schedule_pipelined, search_cuts, solve_admm,
                        solve_balanced_greedy, solve_local_search)
from repro.core.balanced_greedy import assign_balanced
from repro.profiling.scenarios import cnn_instance, instance_builder_for
from repro.profiling.testbed_models import TESTBED_MODELS


def run_cut_search(models=("resnet101", "vgg19"), J=10, I=2, seeds=(0, 1)):
    rows = []
    for model in models:
        tm = TESTBED_MODELS[model]
        for seed in seeds:
            builder = instance_builder_for(model, J, I, seed=seed)
            fixed = builder([tm.default_cut] * J)
            base = solve_balanced_greedy(fixed).makespan
            res = search_cuts(builder, tm.num_layers, J,
                              init_cut=tm.default_cut, rounds=2, stride=2)
            rows.append({
                "model": model, "seed": seed, "fixed_cut": base,
                "searched": res.makespan,
                "gain_pct": round(100.0 * (base - res.makespan) / base, 1),
                "evals": res.evaluations,
            })
    return rows


def run_pipelining(model="vgg19", J=12, I=3, Ks=(1, 2, 4, 8), seeds=(0, 1)):
    rows = []
    for K in Ks:
        gains, mks = [], []
        for seed in seeds:
            inst = cnn_instance(model, J=J, I=I, scenario=2, seed=seed)
            assign = assign_balanced(inst)
            res = schedule_pipelined(inst, assign, K)
            gains.append(res.gain_pct)
            mks.append(res.makespan)
        rows.append({"model": model, "K": K,
                     "makespan": round(float(np.mean(mks)), 1),
                     "gain_vs_sequential_pct": round(float(np.mean(gains)), 1)})
    return rows


def main():
    print("-- per-client cut-layer co-optimization (paper future work) --")
    rows1 = run_cut_search()
    print(f"{'model':10s} seed  fixed  searched  gain%  evals")
    for r in rows1:
        print(f"{r['model']:10s} {r['seed']:4d} {r['fixed_cut']:6d} "
              f"{r['searched']:9d} {r['gain_pct']:6.1f} {r['evals']:6d}")

    print("\n-- multi-batch pipelining vs batch-by-batch --")
    rows2 = run_pipelining()
    print("  K  makespan  gain_vs_Kx_single%")
    for r in rows2:
        print(f"{r['K']:3d} {r['makespan']:9.1f} "
              f"{r['gain_vs_sequential_pct']:19.1f}")
    return rows1 + rows2


if __name__ == "__main__":
    main()
