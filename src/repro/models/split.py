"""Split-model execution: part-1 / part-2 / part-3 (Sec. I, Fig. 2).

``split_params`` carves the stacked parameter tree at the cut layers
(sigma_1, sigma_2). Layer kinds are STATIC structure (``SplitSpec``), kept
out of the parameter pytrees so parts jit/vjp cleanly. Each part is executed
by its own pure function so that clients and helpers hold ONLY their own
parameters, and gradients flow across the cuts exactly as in real split
learning: activations travel forward, cotangents travel backward
(chained ``jax.vjp``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .norms import apply_norm
from .transformer import (Runtime, block_forward, cross_entropy, layer_table)


@dataclasses.dataclass(frozen=True)
class SplitSpec:
    """Static structure of a (sigma_1, sigma_2) split."""
    cut: Tuple[int, int]
    kinds1: Tuple[Tuple[str, str], ...]  # (kind, mlp_kind) per layer
    kinds2: Tuple[Tuple[str, str], ...]
    kinds3: Tuple[Tuple[str, str], ...]


def make_split_spec(cfg: ModelConfig,
                    cut: Optional[Tuple[int, int]] = None) -> SplitSpec:
    s1, s2 = cut if cut is not None else cfg.sl_cuts_resolved
    table = layer_table(cfg)
    kinds = [(k, m) for k, m, _, _ in table]
    return SplitSpec(cut=(s1, s2), kinds1=tuple(kinds[:s1]),
                     kinds2=tuple(kinds[s1:s2]), kinds3=tuple(kinds[s2:]))


def split_params(cfg: ModelConfig, params,
                 cut: Optional[Tuple[int, int]] = None):
    """Returns (spec, p1, p2, p3). Each part's "layers" is a LIST of
    per-layer parameter trees (arrays only)."""
    spec = make_split_spec(cfg, cut)
    s1, s2 = spec.cut
    table = layer_table(cfg)

    def layer_blocks(lo, hi):
        out = []
        for li in range(lo, hi):
            _, _, key, pos = table[li]
            bp = params["groups"][key]
            if key != "shared":
                bp = jax.tree.map(lambda a: a[pos], bp)
            out.append(bp)
        return out

    p1 = {"embed": params["embed"], "layers": layer_blocks(0, s1)}
    p2 = {"layers": layer_blocks(s1, s2)}
    p3 = {"layers": layer_blocks(s2, cfg.num_layers),
          "final_norm": params["final_norm"]}
    if not cfg.tie_embeddings:
        p3["lm_head"] = params["lm_head"]
    else:
        p3["embed_out"] = params["embed"]  # tied head travels with part-3
    return spec, p1, p2, p3


def _run_layers(cfg: ModelConfig, kinds, layers: List, x, positions,
                rt: Runtime):
    for (kind, mlp_kind), bp in zip(kinds, layers):
        x, _, _ = block_forward(cfg, kind, mlp_kind, bp, x, positions, rt)
    return x


def part1_forward(cfg: ModelConfig, spec: SplitSpec, p1, batch: Dict,
                  rt: Runtime):
    """Client-side: embed + layers [0, s1). Returns activations of sigma_1."""
    if cfg.frontend == "audio":
        x = batch["frames"]
    else:
        tokens = batch["tokens"]
        e = p1["embed"][tokens]
        x = e * jnp.asarray(jnp.sqrt(jnp.float32(cfg.d_model)), e.dtype)
        if cfg.frontend == "vision":
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return _run_layers(cfg, spec.kinds1, p1["layers"], x, positions, rt)


def part2_forward(cfg: ModelConfig, spec: SplitSpec, p2, acts, rt: Runtime):
    """Helper-side: layers [s1, s2). acts: [B, S, d] from the client."""
    B, S = acts.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return _run_layers(cfg, spec.kinds2, p2["layers"], acts, positions, rt)


def part3_forward_loss(cfg: ModelConfig, spec: SplitSpec, p3, acts,
                       batch: Dict, rt: Runtime):
    """Client-side: layers [s2, L) + head + loss."""
    B, S = acts.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = _run_layers(cfg, spec.kinds3, p3["layers"], acts, positions, rt)
    h = apply_norm(h, p3["final_norm"], cfg.norm)
    head = p3.get("lm_head")
    if head is not None:
        logits = jnp.einsum("bsd,dv->bsv", h, head)
    else:
        logits = jnp.einsum("bsd,vd->bsv", h, p3["embed_out"])
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.frontend == "audio":
        return cross_entropy(logits, batch["labels"])
    S_text = batch["tokens"].shape[1]
    tl = logits[:, -S_text:]
    return cross_entropy(tl[:, :-1], batch["tokens"][:, 1:])


def sl_batch_grads(cfg: ModelConfig, spec: SplitSpec, p1, p2, p3, batch,
                   rt: Runtime):
    """One SL batch update's gradients, with TRUE split gradient flow.

    Returns (loss, g1, g2, g3, traffic) where traffic reports the bytes that
    crossed each cut (matching the cost model's r/l/l'/r' legs).
    """
    a1, vjp1 = jax.vjp(lambda p: part1_forward(cfg, spec, p, batch, rt), p1)
    a2, vjp2 = jax.vjp(lambda p, a: part2_forward(cfg, spec, p, a, rt), p2, a1)
    loss, vjp3 = jax.vjp(
        lambda p, a: part3_forward_loss(cfg, spec, p, a, batch, rt), p3, a2)
    g3, g_a2 = vjp3(jnp.ones_like(loss))
    g2, g_a1 = vjp2(g_a2)
    (g1,) = vjp1(g_a1)
    traffic = {
        "cut1_bytes": a1.size * a1.dtype.itemsize,
        "cut2_bytes": a2.size * a2.dtype.itemsize,
    }
    return loss, g1, g2, g3, traffic
