"""Nemotron-4-340B [arXiv:2402.16819 / 2406.11704]: dense GQA, squared-ReLU MLP."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    block_pattern=("attn",),
    mlp_kind="relu2",
    rope_theta=10000.0,
    tie_embeddings=False,
    sl_cut=(2, 94),
)
