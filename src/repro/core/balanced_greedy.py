"""balanced-greedy — the scalable heuristic of Sec. VI.

Step 1: static load-balancing assignment. For each client j, among helpers
with enough free memory (Q_j), pick the one with the fewest assigned clients
(G_i). Step 2: non-preemptive FCFS scheduling per helper — fwd tasks ordered
by release times r, bwd tasks by c^f + l + l'.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from . import baker
from .instance import Instance
from .schedule import Schedule, check_feasible


@dataclasses.dataclass
class GreedyResult:
    schedule: Schedule
    makespan: int
    runtime_s: float


def assign_balanced(inst: Instance, *, order: Optional[List[int]] = None) -> np.ndarray:
    """Least-loaded feasible helper per client (load = #assigned clients)."""
    load = np.zeros(inst.I, dtype=np.int64)
    free_mem = inst.m.astype(np.float64).copy()
    assign = np.full(inst.J, -1, dtype=np.int64)
    for j in order if order is not None else range(inst.J):
        Q = [i for i in range(inst.I)
             if inst.is_edge(i, j) and free_mem[i] >= inst.d[j]]
        if not Q:
            raise ValueError(f"client {j}: no helper with enough free memory")
        eta = min(Q, key=lambda i: (load[i], i))
        assign[j] = eta
        load[eta] += 1
        free_mem[eta] -= inst.d[j]
    return assign


def schedule_fcfs(inst: Instance, assign: np.ndarray,
                  *, horizon: Optional[int] = None) -> Schedule:
    """Non-preemptive FCFS per helper, fwd first by r, then bwd by c^f + l + l'.

    Fwd and bwd tasks share the helper: bwd tasks are queued into the slots
    left free once they are released, still non-preemptively.
    """
    T = int(horizon if horizon is not None else inst.T)
    x_slots: List[np.ndarray] = [np.array([], dtype=np.int64)] * inst.J
    z_slots: List[np.ndarray] = [np.array([], dtype=np.int64)] * inst.J
    for i in range(inst.I):
        clients = [j for j in range(inst.J) if int(assign[j]) == i]
        if not clients:
            continue
        fjobs = [baker.Job(job_id=j, release=int(inst.r[i, j]),
                           proc=int(inst.p[i, j]), tail=0) for j in clients]
        fsol = baker.fcfs_nonpreemptive(fjobs, lambda t: True, T)
        occupied = set()
        for j in clients:
            x_slots[j] = fsol[j]
            occupied.update(int(t) for t in fsol[j])
        bjobs = []
        for j in clients:
            phi_f = int(fsol[j][-1]) + 1
            release = phi_f + int(inst.l[i, j]) + int(inst.lp[i, j])
            bjobs.append(baker.Job(job_id=j, release=release,
                                   proc=int(inst.pp[i, j]), tail=0))
        bsol = baker.fcfs_nonpreemptive(bjobs, lambda t: t not in occupied, T)
        for j in clients:
            z_slots[j] = bsol[j]
    return Schedule(assign=np.asarray(assign, dtype=np.int64).copy(),
                    x_slots=x_slots, z_slots=z_slots)


def solve_balanced_greedy(inst: Instance, *, horizon: Optional[int] = None) -> GreedyResult:
    t0 = time.perf_counter()
    T = int(horizon if horizon is not None else inst.T)
    assign = assign_balanced(inst)
    sched = schedule_fcfs(inst, assign, horizon=T)
    check_feasible(inst, sched, horizon=T)
    return GreedyResult(schedule=sched, makespan=sched.makespan(inst),
                        runtime_s=time.perf_counter() - t0)
