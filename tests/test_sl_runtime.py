"""Integration tests: parallel-SL runtime + simulator + scenario generators."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (check_feasible, solve_admm, solve_balanced_greedy,
                        solve_baseline)
from repro.data.synthetic import SyntheticLM, make_batch
from repro.profiling.scenarios import cnn_instance, transformer_instance
from repro.profiling.cost_model import split_costs, count_params
from repro.sl.runtime import ParallelSLTrainer
from repro.sl.simulator import gantt, simulate


@pytest.fixture(scope="module")
def small_sl_setup():
    cfg = get_config("gemma2-2b").reduced(num_layers=2, d_model=64, vocab=128)
    inst = transformer_instance(cfg, J=3, I=2, scenario=2, seed=0,
                                slot_s=0.05, batch=2, seq=32)
    res = solve_admm(inst, mode="fast", tau_max=4)
    return cfg, inst, res.schedule


def test_sl_training_loss_decreases(small_sl_setup):
    cfg, inst, sched = small_sl_setup
    trainer = ParallelSLTrainer(cfg, inst, sched, lr=5e-3)
    gen = SyntheticLM(cfg.vocab_size, 32, 2, seed=0)
    batches = [next(gen.batches(1)) for _ in range(inst.J)]
    first = trainer.run_round(batches, local_steps=2).mean_loss
    for _ in range(4):
        last = trainer.run_round(batches, local_steps=2).mean_loss
    assert last < first - 0.2, (first, last)


def test_simulator_matches_analytic_makespan(small_sl_setup):
    cfg, inst, sched = small_sl_setup
    rep = simulate(inst, sched)
    assert rep.makespan == sched.makespan(inst)
    assert set(rep.helper_util) == set(range(inst.I))
    assert all(0 <= u <= 1 for u in rep.helper_util.values())
    g = gantt(inst, sched)
    assert g.count("\n") == inst.I - 1


def test_fedavg_synchronizes_versions(small_sl_setup):
    cfg, inst, sched = small_sl_setup
    trainer = ParallelSLTrainer(cfg, inst, sched, lr=5e-3)
    gen = SyntheticLM(cfg.vocab_size, 32, 2, seed=0)
    batches = [next(gen.batches(1)) for _ in range(inst.J)]
    trainer.run_round(batches)
    # after aggregation all clients hold identical part-1 copies
    import jax
    l0 = jax.tree.leaves(trainer.client_p1[0])
    for j in range(1, inst.J):
        lj = jax.tree.leaves(trainer.client_p1[j])
        for a, b in zip(l0, lj):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("model,scenario", [("resnet101", 1), ("vgg19", 2)])
def test_cnn_instances_solvable(model, scenario):
    inst = cnn_instance(model, J=10, I=2, scenario=scenario, seed=1)
    for res in (solve_baseline(inst, seed=0), solve_balanced_greedy(inst),
                solve_admm(inst, mode="fast", tau_max=4)):
        check_feasible(inst, res.schedule)


def test_transformer_instance_all_archs():
    """Every assigned architecture can be scheduled by the paper's methods
    (technique applicability — DESIGN.md §Arch-applicability)."""
    from repro.configs import ARCHS
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        inst = transformer_instance(cfg, J=4, I=2, scenario=1, seed=0,
                                    batch=2, seq=128, slot_s=1.0,
                                    helper_flops_mult=4.0)
        res = solve_balanced_greedy(inst)
        check_feasible(inst, res.schedule)


def test_split_costs_consistency():
    cfg = get_config("phi3-medium-14b")
    c = split_costs(cfg, 8, 512)
    total = sum(c.fwd_flops)
    # parts must sum to the full model forward
    from repro.profiling.cost_model import model_fwd_flops
    assert abs(total - model_fwd_flops(cfg, 8, 512)) / total < 1e-9
    assert c.cut1_bytes == 8 * 512 * cfg.d_model * 2


def test_count_params_matches_init():
    import jax
    cfg = get_config("granite-moe-1b-a400m").reduced()
    from repro.models.transformer import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert count_params(cfg) == real
