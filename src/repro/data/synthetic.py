"""Deterministic synthetic data pipelines (offline container: no downloads).

Token streams follow a Zipfian unigram mixed with copy structure so the loss
actually decreases during training (pure-uniform tokens cannot be learned).
Vision/audio pipelines emit stub frontend embeddings per the task spec.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    """Markov-ish synthetic language: learnable bigram structure."""
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        # sparse bigram table: each token has 4 likely successors
        self._succ = rng.integers(0, V, size=(V, 4))
        self._zipf = 1.0 / np.arange(1, V + 1)
        self._zipf /= self._zipf.sum()

    def batches(self, num: int) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed + 1)
        for _ in range(num):
            toks = np.empty((self.batch_size, self.seq_len), np.int32)
            toks[:, 0] = rng.choice(self.vocab_size, size=self.batch_size,
                                    p=self._zipf)
            for t in range(1, self.seq_len):
                follow = rng.random(self.batch_size) < 0.8
                pick = self._succ[toks[:, t - 1], rng.integers(0, 4, self.batch_size)]
                rand = rng.choice(self.vocab_size, size=self.batch_size,
                                  p=self._zipf)
                toks[:, t] = np.where(follow, pick, rand)
            yield {"tokens": toks}


def make_batch(cfg: ModelConfig, batch_size: int, seq_len: int, *,
               seed: int = 0) -> Dict[str, np.ndarray]:
    """One batch matching the arch's input signature (incl. modality stubs)."""
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        return {
            "frames": rng.normal(0, 1, (batch_size, seq_len, cfg.d_model))
            .astype(np.float32),
            "labels": rng.integers(0, cfg.vocab_size,
                                   (batch_size, seq_len)).astype(np.int32),
        }
    batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                    (batch_size, seq_len)).astype(np.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = rng.normal(
            0, 1, (batch_size, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
    return batch


def client_shards(cfg: ModelConfig, num_clients: int, samples_per_client: int,
                  seq_len: int, *, seed: int = 0):
    """Per-client local datasets for the parallel-SL runtime (FL-style)."""
    gen = SyntheticLM(cfg.vocab_size, seq_len, samples_per_client, seed=seed)
    return [next(gen.batches(1)) for _ in range(num_clients)]
